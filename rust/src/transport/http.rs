//! Minimal HTTP/1.1 server and client over `std::net`.
//!
//! A deliberate substrate: the offline crate cache has no tokio/hyper, and
//! the paper's controller is a plain REST server. We implement exactly what
//! the protocol needs:
//!
//! * POST with `Content-Length` bodies, responses `200 OK`.
//! * Per-request codec negotiation: bodies are JSON
//!   (`application/json`, the paper's format and the default) or the
//!   compact binary codec (`application/x-safe-binary`). The server
//!   decodes by the request's `Content-Type` and answers in the same
//!   format, so mixed-codec clients can share one controller.
//! * Keep-alive connections (one learner holds one connection).
//! * Thread-per-connection server — correct for long-polling handlers that
//!   block inside the controller (a blocked poll only parks its own thread).
//! * Graceful shutdown via a poison connection.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::{as_transport_error, ClientTransport, Handler, MessageStats, TransportError};
use crate::json::Value;
use crate::proto::codec::{WireCodec, WireFormat, CONTENT_TYPE_JSON};

/// Threaded HTTP server wrapping a [`Handler`].
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind to `addr` (use port 0 for an ephemeral port) and start serving.
    pub fn start(addr: &str, handler: Arc<dyn Handler>) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let shutdown2 = shutdown.clone();
        let accept_thread = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if shutdown2.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            let h = handler.clone();
                            let sd = shutdown2.clone();
                            let _ = std::thread::Builder::new()
                                .name("http-conn".into())
                                .spawn(move || {
                                    let _ = serve_connection(s, h, sd);
                                });
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(HttpServer { addr: local, shutdown, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Poke the accept loop so it notices the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_connection(
    stream: TcpStream,
    handler: Arc<dyn Handler>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // clean EOF
            Err(e) => {
                let _ = write_response(
                    &mut stream,
                    400,
                    format!("{{\"error\":\"{e}\"}}").as_bytes(),
                    CONTENT_TYPE_JSON,
                );
                return Ok(());
            }
        };
        // Negotiate the codec from the request's Content-Type; the
        // response is written in the same format.
        let format = req
            .content_type
            .as_deref()
            .map(WireFormat::from_content_type)
            .unwrap_or(WireFormat::Json);
        let codec = format.codec();
        let body_value = if req.body.is_empty() {
            Value::obj()
        } else {
            match codec.decode(&req.body) {
                Ok(v) => v,
                Err(e) => {
                    write_response(
                        &mut stream,
                        400,
                        format!("{{\"error\":\"bad body: {e}\"}}").as_bytes(),
                        CONTENT_TYPE_JSON,
                    )?;
                    continue;
                }
            }
        };
        let resp = handler.handle(&req.path, &body_value);
        if req.path == crate::proto::METRICS {
            // Prometheus scrapers expect the raw text exposition, not a
            // codec-wrapped envelope: unwrap the handler's `"text"` field
            // and serve it with the exposition-format content type.
            let text = resp.str_of("text").unwrap_or_default();
            write_response(&mut stream, 200, text.as_bytes(), "text/plain; version=0.0.4")?;
        } else {
            write_response(&mut stream, 200, &codec.encode(&resp), codec.content_type())?;
        }
        if !req.keep_alive {
            return Ok(());
        }
    }
}

struct Request {
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
    content_type: Option<String>,
}

fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<Request>> {
    let mut line = String::new();
    let n = reader.read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.1").to_string();
    if method != "POST" && method != "GET" {
        bail!("unsupported method {method}");
    }
    let mut content_length = 0usize;
    let mut content_type = None;
    let mut keep_alive = version.ends_with("1.1");
    loop {
        let mut h = String::new();
        let n = reader.read_line(&mut h)?;
        if n == 0 {
            bail!("connection closed mid-headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim().to_ascii_lowercase();
            let v = v.trim();
            if k == "content-length" {
                content_length = v.parse().context("bad content-length")?;
            } else if k == "connection" {
                keep_alive = !v.eq_ignore_ascii_case("close");
            } else if k == "content-type" {
                content_type = Some(v.to_string());
            }
        }
    }
    const MAX_BODY: usize = 256 << 20; // 256 MiB guard
    if content_length > MAX_BODY {
        bail!("body too large: {content_length}");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Some(Request { path, body, keep_alive, content_type }))
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &[u8],
    content_type: &str,
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// Map a socket error to its typed transport cause: a clean EOF means the
/// peer closed the connection (retryable via reconnect), anything else —
/// including read timeouts and unparseable framing — is an I/O fault.
fn io_err(e: std::io::Error) -> anyhow::Error {
    let kind = if e.kind() == std::io::ErrorKind::UnexpectedEof {
        TransportError::ConnectionClosed
    } else {
        TransportError::Io
    };
    anyhow::Error::new(kind).context(e.to_string())
}

/// HTTP client transport with a persistent keep-alive connection.
pub struct HttpTransport {
    addr: SocketAddr,
    conn: Mutex<Option<TcpStream>>,
    stats: Arc<MessageStats>,
    codec: &'static dyn WireCodec,
    /// Read timeout; must exceed the controller's long-poll window.
    pub read_timeout: Duration,
    /// Observability sink for per-request completion latency (additive:
    /// never touches the `MessageStats` accounting).
    latency_metrics: Option<Arc<crate::metrics::LatencyRecorder>>,
}

impl HttpTransport {
    pub fn connect(url: &str) -> Result<HttpTransport> {
        let addr_str = url.strip_prefix("http://").unwrap_or(url);
        let addr: SocketAddr = addr_str.parse().with_context(|| format!("bad address {url}"))?;
        Ok(HttpTransport {
            addr,
            conn: Mutex::new(None),
            stats: Arc::new(MessageStats::default()),
            codec: WireFormat::Json.codec(),
            read_timeout: Duration::from_secs(600),
            latency_metrics: None,
        })
    }

    /// Select the wire codec (builder-style; JSON is the default).
    pub fn with_wire_format(mut self, format: WireFormat) -> Self {
        self.codec = format.codec();
        self
    }

    /// Builder: attach a request-latency recorder (observed on every
    /// successful `call`, wall time across retries — what the caller
    /// actually waited).
    pub fn with_latency_metrics(
        mut self,
        recorder: Arc<crate::metrics::LatencyRecorder>,
    ) -> Self {
        self.latency_metrics = Some(recorder);
        self
    }

    pub fn stats(&self) -> Arc<MessageStats> {
        self.stats.clone()
    }

    fn request_once(&self, stream: &mut TcpStream, path: &str, body: &[u8]) -> Result<Value> {
        let head = format!(
            "POST {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\r\n",
            self.addr,
            self.codec.content_type(),
            body.len()
        );
        stream.write_all(head.as_bytes()).map_err(io_err)?;
        stream.write_all(body).map_err(io_err)?;
        stream.flush().map_err(io_err)?;

        let mut reader = BufReader::new(stream.try_clone().map_err(io_err)?);
        let mut status_line = String::new();
        reader.read_line(&mut status_line).map_err(io_err)?;
        if status_line.is_empty() {
            return Err(anyhow::Error::new(TransportError::ConnectionClosed)
                .context("server closed connection"));
        }
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .ok_or(TransportError::Io)
            .context("bad status line")?
            .parse()
            .map_err(|_| TransportError::Io)
            .context("bad status code")?;
        let mut content_length = 0usize;
        let mut content_type: Option<String> = None;
        loop {
            let mut h = String::new();
            let n = reader.read_line(&mut h).map_err(io_err)?;
            if n == 0 {
                return Err(anyhow::Error::new(TransportError::ConnectionClosed)
                    .context("connection closed mid-headers"));
            }
            if h.trim_end().is_empty() {
                break;
            }
            if let Some((k, v)) = h.trim_end().split_once(':') {
                let k = k.trim();
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v
                        .trim()
                        .parse()
                        .map_err(|_| TransportError::Io)
                        .context("bad content-length")?;
                } else if k.eq_ignore_ascii_case("content-type") {
                    content_type = Some(v.trim().to_string());
                }
            }
        }
        let mut resp_body = vec![0u8; content_length];
        reader.read_exact(&mut resp_body).map_err(io_err)?;
        if status != 200 {
            return Err(anyhow::Error::new(TransportError::BadStatus(status)).context(format!(
                "HTTP {status}: {}",
                String::from_utf8_lossy(&resp_body)
            )));
        }
        // The server mirrors the request codec, but decode by the actual
        // response Content-Type so mixed deployments stay interoperable.
        let resp_format = content_type
            .as_deref()
            .map(WireFormat::from_content_type)
            .unwrap_or(WireFormat::Json);
        let v = resp_format.codec().decode(&resp_body)?;
        // Record only after a successful decode: a failed attempt is
        // retried by call(), and recording it would double-count
        // bytes_received/codec bytes against a single message.
        self.stats.record_response(path, resp_body.len());
        self.stats.record_codec(resp_format, resp_body.len());
        Ok(v)
    }
}

impl ClientTransport for HttpTransport {
    fn call(&self, path: &str, body: &Value) -> Result<Value> {
        let started = std::time::Instant::now();
        let body_bytes = self.codec.encode(body);
        self.stats.record(path, body_bytes.len());
        self.stats.record_codec(self.codec.format(), body_bytes.len());
        let mut guard = self.conn.lock().unwrap();
        // Try on the cached connection first, reconnect once on failure —
        // but only for retryable faults: a fatal answer (non-200) means
        // the server received and rejected the request, and resending it
        // would risk the very duplicate posts the dedup token guards.
        for attempt in 0..2 {
            if guard.is_none() {
                let s = TcpStream::connect(self.addr).map_err(|e| {
                    anyhow::Error::new(TransportError::ConnectFailed)
                        .context(format!("connect {}: {e}", self.addr))
                })?;
                s.set_nodelay(true).ok();
                s.set_read_timeout(Some(self.read_timeout)).ok();
                *guard = Some(s);
            }
            let stream = guard.as_mut().unwrap();
            match self.request_once(stream, path, &body_bytes) {
                Ok(v) => {
                    if path == crate::proto::POST_AGGREGATE
                        && v.str_of("status") == Some("duplicate")
                    {
                        self.stats.record_dedup();
                    }
                    if let Some(r) = &self.latency_metrics {
                        r.observe(path, started.elapsed());
                    }
                    return Ok(v);
                }
                Err(e)
                    if attempt == 0
                        && as_transport_error(&e).map_or(true, |t| t.retryable()) =>
                {
                    *guard = None; // drop stale connection and retry
                    self.stats.record_retry();
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!()
    }

    fn message_count(&self) -> u64 {
        self.stats.total()
    }

    fn bytes_sent(&self) -> u64 {
        self.stats.bytes()
    }

    fn bytes_received(&self) -> u64 {
        self.stats.bytes_received()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Handler for Echo {
        fn handle(&self, path: &str, body: &Value) -> Value {
            Value::object(vec![("path", Value::from(path)), ("echo", body.clone())])
        }
    }

    struct SlowHandler;
    impl Handler for SlowHandler {
        fn handle(&self, _path: &str, _body: &Value) -> Value {
            std::thread::sleep(Duration::from_millis(150));
            Value::object(vec![("done", Value::from(true))])
        }
    }

    #[test]
    fn http_roundtrip() {
        let server = HttpServer::start("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let client = HttpTransport::connect(&server.url()).unwrap();
        let body = Value::object(vec![("node", Value::from(3u64)), ("agg", Value::from("x:y:z"))]);
        let resp = client.call("/post_aggregate", &body).unwrap();
        assert_eq!(resp.str_of("path"), Some("/post_aggregate"));
        assert_eq!(resp.get("echo"), Some(&body));
    }

    #[test]
    fn http_binary_codec_roundtrip() {
        let server = HttpServer::start("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let client = HttpTransport::connect(&server.url())
            .unwrap()
            .with_wire_format(WireFormat::Binary);
        let vec: Vec<f64> = (0..256).map(|i| i as f64 * 0.375 - 10.0).collect();
        let body = Value::object(vec![
            ("node", Value::from(3u64)),
            ("vector", Value::from(vec.clone())),
        ]);
        let resp = client.call("/insec/post", &body).unwrap();
        assert_eq!(resp.get("echo").unwrap().f64_arr_of("vector").unwrap(), vec);
        assert!(client.stats().codec_bytes(WireFormat::Binary) > 0);
    }

    #[test]
    fn http_mixed_codec_clients_share_one_server() {
        let server = HttpServer::start("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let json_client = HttpTransport::connect(&server.url()).unwrap();
        let bin_client = HttpTransport::connect(&server.url())
            .unwrap()
            .with_wire_format(WireFormat::Binary);
        // Full-mantissa floats (raw f64 beats decimal text only when the
        // decimals are long, as real aggregation output is).
        let v: Vec<f64> = (0..64).map(|i| i as f64 * 0.707_106_781_186_547_6).collect();
        let body = Value::object(vec![("v", Value::from(v))]);
        let rj = json_client.call("/x", &body).unwrap();
        let rb = bin_client.call("/x", &body).unwrap();
        assert_eq!(rj, rb);
        assert!(bin_client.bytes_sent() < json_client.bytes_sent());
    }

    #[test]
    fn http_deflate_codec_negotiation() {
        let server = HttpServer::start("127.0.0.1:0", Arc::new(Echo)).unwrap();
        for fmt in [WireFormat::JsonDeflate, WireFormat::BinaryDeflate] {
            let client = HttpTransport::connect(&server.url())
                .unwrap()
                .with_wire_format(fmt);
            let body = Value::object(vec![
                ("node", Value::from(3u64)),
                ("blob", Value::Bytes(crate::blob::Blob::new(vec![0xe7u8; 512]))),
            ]);
            let resp = client.call("/x", &body).unwrap();
            assert_eq!(resp.get("echo"), Some(&body), "{}", fmt.name());
            assert!(client.stats().codec_bytes(fmt) > 0);
        }
    }

    #[test]
    fn http_keepalive_multiple_requests() {
        let server = HttpServer::start("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let client = HttpTransport::connect(&server.url()).unwrap();
        for i in 0..20u64 {
            let resp = client
                .call("/x", &Value::object(vec![("i", Value::from(i))]))
                .unwrap();
            assert_eq!(resp.get("echo").unwrap().u64_of("i"), Some(i));
        }
        assert_eq!(client.message_count(), 20);
    }

    #[test]
    fn http_concurrent_clients_with_blocking_handler() {
        let server = HttpServer::start("127.0.0.1:0", Arc::new(SlowHandler)).unwrap();
        let url = server.url();
        let start = std::time::Instant::now();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let url = url.clone();
                std::thread::spawn(move || {
                    let client = HttpTransport::connect(&url).unwrap();
                    client.call("/slow", &Value::obj()).unwrap()
                })
            })
            .collect();
        for t in threads {
            let resp = t.join().unwrap();
            assert_eq!(resp.bool_of("done"), Some(true));
        }
        // Thread-per-connection: 8 × 150 ms handlers must overlap.
        assert!(start.elapsed() < Duration::from_millis(800), "handlers did not run concurrently");
    }

    #[test]
    fn http_large_body() {
        let server = HttpServer::start("127.0.0.1:0", Arc::new(Echo)).unwrap();
        let client = HttpTransport::connect(&server.url()).unwrap();
        let big: Vec<f64> = (0..50_000).map(|i| i as f64).collect();
        let resp = client
            .call("/big", &Value::object(vec![("v", Value::from(big.clone()))]))
            .unwrap();
        assert_eq!(resp.get("echo").unwrap().f64_arr_of("v").unwrap(), big);
    }

    /// Read until the whole client request (headers + the `{}` JSON body
    /// the typed-error tests send) has arrived, so responding/closing
    /// never races the client's writes into an RST.
    fn drain_request(s: &mut TcpStream) {
        let mut data = Vec::new();
        let mut buf = [0u8; 1024];
        loop {
            match s.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => {
                    data.extend_from_slice(&buf[..n]);
                    if data.windows(4).any(|w| w == b"\r\n\r\n") && data.ends_with(b"{}") {
                        break;
                    }
                }
            }
        }
    }

    #[test]
    fn typed_error_connect_failed() {
        // Bind then drop a listener so the port is (almost surely) dead.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let client = HttpTransport::connect(&format!("http://{addr}")).unwrap();
        let err = client.call("/x", &Value::obj()).unwrap_err();
        assert_eq!(as_transport_error(&err), Some(TransportError::ConnectFailed));
    }

    #[test]
    fn typed_error_connection_closed() {
        // A "server" that accepts and immediately hangs up, twice (the
        // client's internal reconnect burns the second accept).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let accept = std::thread::spawn(move || {
            for _ in 0..2 {
                // Drain the full request, then close cleanly (FIN, no
                // reply): the client sees EOF where a status line should
                // be. Draining fully avoids an RST racing the client's
                // writes, which would surface as Io instead.
                let (mut s, _) = listener.accept().unwrap();
                drain_request(&mut s);
            }
        });
        let client = HttpTransport::connect(&format!("http://{addr}")).unwrap();
        let err = client.call("/x", &Value::obj()).unwrap_err();
        assert_eq!(as_transport_error(&err), Some(TransportError::ConnectionClosed));
        assert_eq!(client.stats().retries(), 1);
        accept.join().unwrap();
    }

    #[test]
    fn typed_error_bad_status_is_fatal_and_not_retried() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let accept = std::thread::spawn(move || {
            // One connection is enough: a fatal status must not reconnect.
            let (mut s, _) = listener.accept().unwrap();
            drain_request(&mut s);
            s.write_all(b"HTTP/1.1 503 Unavailable\r\nContent-Length: 4\r\n\r\nbusy")
                .unwrap();
        });
        let client = HttpTransport::connect(&format!("http://{addr}")).unwrap();
        let err = client.call("/x", &Value::obj()).unwrap_err();
        assert_eq!(as_transport_error(&err), Some(TransportError::BadStatus(503)));
        assert!(!TransportError::BadStatus(503).retryable());
        assert_eq!(client.stats().retries(), 0);
        accept.join().unwrap();
    }

    #[test]
    fn typed_error_io_on_garbled_response() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let accept = std::thread::spawn(move || {
            for _ in 0..2 {
                let (mut s, _) = listener.accept().unwrap();
                drain_request(&mut s);
                let _ = s.write_all(b"NOT-HTTP\r\n\r\n");
            }
        });
        let client = HttpTransport::connect(&format!("http://{addr}")).unwrap();
        let err = client.call("/x", &Value::obj()).unwrap_err();
        assert_eq!(as_transport_error(&err), Some(TransportError::Io));
        accept.join().unwrap();
    }

    #[test]
    fn server_survives_bad_requests() {
        let server = HttpServer::start("127.0.0.1:0", Arc::new(Echo)).unwrap();
        // Send garbage on a raw socket.
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(b"NONSENSE\r\n\r\n").unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        // Server should still answer proper requests afterwards.
        let client = HttpTransport::connect(&server.url()).unwrap();
        let resp = client.call("/ok", &Value::obj()).unwrap();
        assert_eq!(resp.str_of("path"), Some("/ok"));
    }
}
