//! Transport substrate: how learners reach the controller.
//!
//! The paper runs REST/HTTPS between multi-threaded clients and a Flask
//! server (§2, §6). We provide two interchangeable transports behind the
//! same request/response interface:
//!
//! * [`InProcTransport`] — learners call the controller service directly
//!   (one OS thread per learner, exactly like the paper's edge benchmark
//!   where "each learner node is run concurrently in separate threads").
//!   Optionally injects a per-message latency to model the REST hop.
//! * [`http::HttpTransport`] / [`http::HttpServer`] — a from-scratch
//!   HTTP/1.1 client/server over `std::net` (tokio is not in the offline
//!   crate cache), with keep-alive and long-poll friendly blocking
//!   handlers. Used by the integration tests, the `safe` CLI processes and
//!   the hierarchical-federation example.
//!
//! **Wire codecs.** Every body *really* crosses a serialization boundary
//! in both directions (client encode → server decode, and back), even
//! in-process — that keeps the measured cost faithful to the REST
//! deployment, where the serialization tax drives the paper's Figs 9/12
//! crossovers. The byte format is a pluggable policy
//! ([`proto::codec::WireCodec`]): [`JsonCodec`](crate::proto::codec::JsonCodec)
//! is the default (paper parity), [`BinaryCodec`](crate::proto::codec::BinaryCodec)
//! ships raw little-endian `f64` vectors and length-prefixed fields. The
//! HTTP pair negotiates the codec per request via `Content-Type`; the
//! in-proc transport encodes/decodes with whichever codec the session
//! configured.
//!
//! Every call is counted so the benches can verify the paper's message
//! complexity formulas (`4n`, `4n + 2f`, `(i+1)(4n+2f+in)`, `+g`), and
//! [`MessageStats`] tracks request *and* response bytes, per-codec byte
//! totals (for wire-ratio reporting across all four codec stacks) and a
//! sharded per-path map carrying message counts **and byte totals per
//! direction** ([`PathStat`]) so ratio tables can be broken down by
//! endpoint — all kept off the hot path's single-lock contention.

pub mod error;
pub mod http;
pub mod netprofile;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub use error::{as_transport_error, TransportError};
pub use netprofile::{NetFaults, NetProfile, RetryPolicy};

use crate::json::Value;
use crate::proto::codec::{WireCodec, WireFormat};

/// Server-side request handler (the controller implements this).
/// Handlers may block (long-polling `get_*`/`check_*` ops).
pub trait Handler: Send + Sync {
    fn handle(&self, path: &str, body: &Value) -> Value;
}

/// What a long-poll is waiting *for* — the completion layer's routing key.
///
/// Each key names one controller-side condition that can flip a parked
/// long-poll from "empty" to "ready". The event runtime registers a waiter
/// under the key a probe returned; the controller wakes that key at every
/// state change that can satisfy it (the completion-style mirror of its
/// internal `Condvar::notify_all`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PollKey {
    /// `get_aggregate`: a chain message addressed to `node` in `group`.
    Aggregate { group: u64, node: u64 },
    /// `check_aggregate`: the chain advanced through (or around) `node`.
    Check { group: u64, node: u64 },
    /// `get_average`: every expected group posted its average (§5.5
    /// barrier) — one global key, woken once when the barrier completes.
    Average,
    /// `get_key`: `node`'s public key was registered.
    Key { node: u64 },
    /// `get_preneg_key`: `owner` posted a §5.8 key for `node`.
    Preneg { owner: u64, node: u64 },
    /// `fed_get_global_average`: every expected fan-in child posted its
    /// shard partial (§5.10 barrier) — one global key on the parent.
    FedGlobal,
}

/// One non-blocking probe of a request: either the full response, or the
/// key to wait on for a wakeup.
pub enum TryHandle {
    Ready(Value),
    WouldBlock(PollKey),
}

/// A handler that can answer requests *without parking the caller*: the
/// long-poll predicate is evaluated exactly once under the server lock.
/// Non-long-poll paths must answer `Ready` immediately (the blanket
/// behaviour is to fall through to [`Handler::handle`]).
pub trait NonBlockingHandler: Handler {
    fn try_handle(&self, path: &str, body: &Value) -> TryHandle;

    /// A submission on `path` parked (went pending). Lets the server keep
    /// its §5.9 connection-pressure gauge accurate under the event
    /// runtime, where no OS thread actually blocks.
    fn poll_parked(&self, _path: &str) {}

    /// A parked submission on `path` completed (data or poll timeout).
    fn poll_unparked(&self, _path: &str) {}
}

/// Where completed wakeups go: the event executor's ready queue. Kept as
/// a trait so the transport layer never depends on the executor.
pub trait WakeSink: Send + Sync {
    fn wake(&self, task: u64, generation: u64);
}

/// Registry of parked long-polls, keyed by [`PollKey`].
///
/// The lost-wakeup race (data arrives between a failed probe and the
/// register) is closed by the caller probing *again* after registering;
/// a stale registration is harmless — wakeups carry the submission
/// generation and the executor drops mismatches. Locking: the hub lock
/// nests inside the server's state lock (notify runs under it) and the
/// sink's queue lock nests inside the hub's — never the other way.
#[derive(Default)]
pub struct WaitHub {
    waiters: Mutex<BTreeMap<PollKey, Vec<(u64, u64)>>>,
    sink: Mutex<Option<Arc<dyn WakeSink>>>,
}

impl WaitHub {
    /// Install the executor's ready queue. Must happen before any
    /// `register`; wakes with no sink are dropped (nothing can be waiting).
    pub fn set_sink(&self, sink: Arc<dyn WakeSink>) {
        *self.sink.lock().unwrap() = Some(sink);
    }

    /// Park `(task, generation)` until `key` is woken.
    pub fn register(&self, key: PollKey, task: u64, generation: u64) {
        self.waiters
            .lock()
            .unwrap()
            .entry(key)
            .or_default()
            .push((task, generation));
    }

    /// Wake every waiter parked on `key`.
    pub fn wake(&self, key: PollKey) {
        let drained = match self.waiters.lock().unwrap().remove(&key) {
            Some(w) => w,
            None => return,
        };
        let sink = self.sink.lock().unwrap().clone();
        if let Some(s) = sink {
            for (task, generation) in drained {
                s.wake(task, generation);
            }
        }
    }

    /// Wake everything (configure / begin_round / reset: any predicate
    /// may have changed shape).
    pub fn wake_all(&self) {
        let drained: Vec<(u64, u64)> = {
            let mut map = self.waiters.lock().unwrap();
            let all = map.values().flatten().copied().collect();
            map.clear();
            all
        };
        let sink = self.sink.lock().unwrap().clone();
        if let Some(s) = sink {
            for (task, generation) in drained {
                s.wake(task, generation);
            }
        }
    }
}

/// Outcome of a completion-style submission: either the response (the
/// request *and* response legs were accounted, same as a blocking
/// `call`), or the poll key to wait on (request leg accounted; the
/// response leg is accounted at completion time).
pub enum Submitted {
    Ready(Value),
    Pending(PollKey),
}

/// Client-side view of the wire.
pub trait ClientTransport: Send + Sync {
    fn call(&self, path: &str, body: &Value) -> anyhow::Result<Value>;
    /// Messages sent through this transport so far.
    fn message_count(&self) -> u64;
    /// Bytes sent (request bodies) through this transport so far.
    fn bytes_sent(&self) -> u64;
    /// Bytes received (response bodies) through this transport so far.
    fn bytes_received(&self) -> u64;
}

/// Number of per-path shards. Paths hash across shards so many learner
/// threads recording concurrently rarely contend on the same lock.
const PATH_SHARDS: usize = 8;

/// Per-endpoint traffic totals: message count plus body bytes in each
/// direction, so wire-ratio tables can be broken down by endpoint.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PathStat {
    pub messages: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// Message/byte counters shared by the transports.
///
/// Totals are relaxed atomics (hot path); the per-path map is sharded by
/// path hash so it stays accurate for the §5.2 formula tests without
/// serializing every learner thread through one mutex. Each entry carries
/// a full [`PathStat`] — message counts *and* byte totals per direction.
#[derive(Default)]
pub struct MessageStats {
    total: AtomicU64,
    bytes: AtomicU64,
    bytes_received: AtomicU64,
    /// Request+response bytes that crossed the wire per codec stack.
    json_bytes: AtomicU64,
    binary_bytes: AtomicU64,
    json_deflate_bytes: AtomicU64,
    binary_deflate_bytes: AtomicU64,
    /// Re-sent attempts after a retryable transport failure.
    retries: AtomicU64,
    /// Injected drops observed (request or response leg).
    drops: AtomicU64,
    /// Duplicate posts the controller deduplicated by attempt token.
    dedup_posts: AtomicU64,
    per_path: [Mutex<BTreeMap<String, PathStat>>; PATH_SHARDS],
}

impl MessageStats {
    fn shard(path: &str) -> usize {
        // FNV-1a: cheap and stable; paths are short static strings.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &b in path.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        (h as usize) % PATH_SHARDS
    }

    fn with_path_stat(&self, path: &str, f: impl FnOnce(&mut PathStat)) {
        let mut map = self.per_path[Self::shard(path)].lock().unwrap();
        match map.get_mut(path) {
            Some(s) => f(s),
            None => {
                let mut s = PathStat::default();
                f(&mut s);
                map.insert(path.to_string(), s);
            }
        }
    }

    /// Record one sent request of `bytes` body bytes on `path`.
    pub fn record(&self, path: &str, bytes: usize) {
        self.total.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.with_path_stat(path, |s| {
            s.messages += 1;
            s.bytes_sent += bytes as u64;
        });
    }

    /// Record one received response body of `bytes` bytes, attributed to
    /// the request's `path`.
    pub fn record_response(&self, path: &str, bytes: usize) {
        self.bytes_received.fetch_add(bytes as u64, Ordering::Relaxed);
        self.with_path_stat(path, |s| s.bytes_received += bytes as u64);
    }

    /// Attribute `bytes` wire bytes (either direction) to a codec, so
    /// benches can report wire-size ratios across codec stacks.
    pub fn record_codec(&self, format: WireFormat, bytes: usize) {
        let counter = match format {
            WireFormat::Json => &self.json_bytes,
            WireFormat::Binary => &self.binary_bytes,
            WireFormat::JsonDeflate => &self.json_deflate_bytes,
            WireFormat::BinaryDeflate => &self.binary_deflate_bytes,
        };
        counter.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Count one re-sent attempt after a retryable failure.
    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one injected drop (either leg).
    pub fn record_drop(&self) {
        self.drops.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one duplicate post absorbed by the controller's dedup token.
    pub fn record_dedup(&self) {
        self.dedup_posts.fetch_add(1, Ordering::Relaxed);
    }

    /// Re-sent attempts after retryable transport failures so far.
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Injected request/response-leg drops so far.
    pub fn drops(&self) -> u64 {
        self.drops.load(Ordering::Relaxed)
    }

    /// Duplicate posts absorbed by the dedup token so far.
    pub fn dedup_posts(&self) -> u64 {
        self.dedup_posts.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn bytes_received(&self) -> u64 {
        self.bytes_received.load(Ordering::Relaxed)
    }

    pub fn codec_bytes(&self, format: WireFormat) -> u64 {
        match format {
            WireFormat::Json => self.json_bytes.load(Ordering::Relaxed),
            WireFormat::Binary => self.binary_bytes.load(Ordering::Relaxed),
            WireFormat::JsonDeflate => self.json_deflate_bytes.load(Ordering::Relaxed),
            WireFormat::BinaryDeflate => self.binary_deflate_bytes.load(Ordering::Relaxed),
        }
    }

    /// Message counts per path (the §5.2 formula view).
    pub fn per_path(&self) -> BTreeMap<String, u64> {
        let mut merged = BTreeMap::new();
        for shard in &self.per_path {
            for (k, v) in shard.lock().unwrap().iter() {
                *merged.entry(k.clone()).or_insert(0) += v.messages;
            }
        }
        merged
    }

    /// Full per-path traffic stats: messages + bytes per direction.
    pub fn per_path_stats(&self) -> BTreeMap<String, PathStat> {
        let mut merged: BTreeMap<String, PathStat> = BTreeMap::new();
        for shard in &self.per_path {
            for (k, v) in shard.lock().unwrap().iter() {
                let e = merged.entry(k.clone()).or_default();
                e.messages += v.messages;
                e.bytes_sent += v.bytes_sent;
                e.bytes_received += v.bytes_received;
            }
        }
        merged
    }

    /// Register a scrape-time collector on `registry` that mirrors this
    /// counter set — per-path request/byte counters plus the retry /
    /// drop / dedup totals — under the `shard` label. The registry never
    /// drifts from this source: every scrape re-[`Counter::store`]s the
    /// current totals, so reconciliation with the round accounting is
    /// exact by construction and the hot path records nothing twice.
    /// Both sides are held weakly (the collector dies with whichever is
    /// dropped first, and no `Arc` cycle forms through the registry).
    ///
    /// [`Counter::store`]: crate::metrics::Counter::store
    pub fn mirror_into(
        self: &Arc<Self>,
        registry: &Arc<crate::metrics::MetricRegistry>,
        shard: &str,
    ) {
        use crate::metrics::{names, path_class};
        let stats = Arc::downgrade(self);
        let reg = Arc::downgrade(registry);
        let shard = shard.to_string();
        registry.register_collector(move || {
            let (Some(stats), Some(reg)) = (stats.upgrade(), reg.upgrade()) else {
                return;
            };
            for (path, st) in stats.per_path_stats() {
                let labels = [
                    ("path", path.as_str()),
                    ("shard", shard.as_str()),
                    ("class", path_class(&path)),
                ];
                reg.counter(names::REQUESTS_TOTAL, "Requests per protocol path.", &labels)
                    .store(st.messages);
                reg.counter(
                    names::REQUEST_BYTES_TOTAL,
                    "Request-body bytes per protocol path.",
                    &labels,
                )
                .store(st.bytes_sent);
                reg.counter(
                    names::RESPONSE_BYTES_TOTAL,
                    "Response-body bytes per protocol path.",
                    &labels,
                )
                .store(st.bytes_received);
            }
            let labels = [("shard", shard.as_str())];
            reg.counter(
                names::NET_RETRIES_TOTAL,
                "Attempts re-sent after a retryable transport failure.",
                &labels,
            )
            .store(stats.retries());
            reg.counter(
                names::NET_DROPS_TOTAL,
                "Injected packet drops observed by the transport.",
                &labels,
            )
            .store(stats.drops());
            reg.counter(
                names::DEDUP_POSTS_TOTAL,
                "Duplicate posts absorbed via the attempt-dedup token.",
                &labels,
            )
            .store(stats.dedup_posts());
        });
    }

    pub fn reset(&self) {
        self.total.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
        self.bytes_received.store(0, Ordering::Relaxed);
        self.json_bytes.store(0, Ordering::Relaxed);
        self.binary_bytes.store(0, Ordering::Relaxed);
        self.json_deflate_bytes.store(0, Ordering::Relaxed);
        self.binary_deflate_bytes.store(0, Ordering::Relaxed);
        self.retries.store(0, Ordering::Relaxed);
        self.drops.store(0, Ordering::Relaxed);
        self.dedup_posts.store(0, Ordering::Relaxed);
        for shard in &self.per_path {
            shard.lock().unwrap().clear();
        }
    }
}

/// Direct in-process transport: the default for benchmarks (deterministic,
/// no socket noise) — mirrors the paper's single-machine edge setup.
pub struct InProcTransport {
    handler: Arc<dyn Handler>,
    stats: Arc<MessageStats>,
    codec: &'static dyn WireCodec,
    /// Non-blocking twin of `handler`, present when the event runtime
    /// drives this transport in completion style (`submit`/`try_complete`).
    completion: Option<Arc<dyn NonBlockingHandler>>,
    /// Simulated one-way network latency applied to each call (the REST
    /// hop the paper's numbers include). Zero by default.
    pub latency: Duration,
    /// Simulated transfer cost per KiB of body (request + response) —
    /// models the REST stack's per-byte handling.
    pub per_kib: Duration,
    /// Deterministic fault injection (loss / jitter / stragglers),
    /// shared across every per-node transport of a session. `None` (or
    /// an ideal profile) leaves every path byte-for-byte unchanged.
    net: Option<Arc<NetFaults>>,
    /// Observability sink for per-request completion latency. Purely
    /// additive: recording a histogram observation never touches
    /// `MessageStats`, so the message/byte accounting the formula tests
    /// pin is unchanged whether or not a recorder is attached.
    latency_metrics: Option<Arc<crate::metrics::LatencyRecorder>>,
}

impl InProcTransport {
    pub fn new(handler: Arc<dyn Handler>) -> Self {
        InProcTransport {
            handler,
            stats: Arc::new(MessageStats::default()),
            codec: WireFormat::Json.codec(),
            completion: None,
            latency: Duration::ZERO,
            per_kib: Duration::ZERO,
            net: None,
            latency_metrics: None,
        }
    }

    pub fn with_latency(handler: Arc<dyn Handler>, latency: Duration) -> Self {
        InProcTransport { latency, ..InProcTransport::new(handler) }
    }

    pub fn with_shared_stats(
        handler: Arc<dyn Handler>,
        stats: Arc<MessageStats>,
        latency: Duration,
    ) -> Self {
        InProcTransport { stats, latency, ..InProcTransport::new(handler) }
    }

    /// Full cost model: fixed hop latency + per-KiB transfer cost.
    pub fn with_costs(
        handler: Arc<dyn Handler>,
        stats: Arc<MessageStats>,
        latency: Duration,
        per_kib: Duration,
    ) -> Self {
        InProcTransport { stats, latency, per_kib, ..InProcTransport::new(handler) }
    }

    /// Select the wire codec (builder-style; JSON is the default).
    pub fn with_wire_format(mut self, format: WireFormat) -> Self {
        self.codec = format.codec();
        self
    }

    /// Builder: attach a shared [`NetFaults`] state so this transport
    /// injects the profile's loss/jitter/straggler faults on chain ops.
    pub fn with_net(mut self, net: Arc<NetFaults>) -> Self {
        self.net = Some(net);
        self
    }

    /// Builder: attach a request-latency recorder. Blocking `call`s
    /// observe their own wall time; completion-style submissions are
    /// observed by the event runtime via
    /// [`InProcTransport::observe_latency`] (the transport cannot see a
    /// parked request's full span on its own).
    pub fn with_latency_metrics(
        mut self,
        recorder: Arc<crate::metrics::LatencyRecorder>,
    ) -> Self {
        self.latency_metrics = Some(recorder);
        self
    }

    /// Record one completed request's latency on `path` (no-op without a
    /// recorder attached).
    pub fn observe_latency(&self, path: &str, latency: Duration) {
        if let Some(r) = &self.latency_metrics {
            r.observe(path, latency);
        }
    }

    /// Draw this attempt's fault decision (`None` when exempt/ideal).
    fn net_draw(&self, path: &str, body: &Value) -> Option<netprofile::LinkFault> {
        self.net.as_ref().and_then(|n| n.draw(path, body))
    }

    /// Apply the request-leg fault: extra delay (plus the profile's
    /// bandwidth tax for `bytes`), then possibly drop the request before
    /// the handler runs. Returns `Err` on a drop.
    fn fault_request(
        &self,
        fault: Option<&netprofile::LinkFault>,
        bytes: usize,
    ) -> anyhow::Result<()> {
        let Some(f) = fault else { return Ok(()) };
        let extra = f.request_delay
            + self.net.as_ref().map_or(Duration::ZERO, |n| n.transfer_delay(bytes));
        if !extra.is_zero() {
            std::thread::sleep(extra);
        }
        if f.drop_request {
            self.stats.record_drop();
            return Err(TransportError::LostRequest.into());
        }
        Ok(())
    }

    /// Apply the response-leg fault after the handler ran: possibly drop
    /// the response (side effects already landed), else delay it.
    fn fault_response(&self, fault: Option<&netprofile::LinkFault>) -> anyhow::Result<()> {
        let Some(f) = fault else { return Ok(()) };
        if f.drop_response {
            self.stats.record_drop();
            return Err(TransportError::LostResponse.into());
        }
        if !f.response_delay.is_zero() {
            std::thread::sleep(f.response_delay);
        }
        Ok(())
    }

    /// Count controller-side dedup answers (`status: "duplicate"`) so the
    /// zero-double-count guarantee is observable in the round metrics.
    fn sniff_dedup(&self, path: &str, resp: &Value) {
        if path == crate::proto::POST_AGGREGATE && resp.str_of("status") == Some("duplicate") {
            self.stats.record_dedup();
        }
    }

    fn charge(&self, bytes: usize) {
        let mut d = self.latency;
        if !self.per_kib.is_zero() {
            d += self.per_kib.mul_f64(bytes as f64 / 1024.0);
        }
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }

    pub fn stats(&self) -> Arc<MessageStats> {
        self.stats.clone()
    }

    /// Builder: enable completion-style delivery (`submit`/`try_complete`)
    /// backed by a non-blocking view of the server.
    pub fn with_completion(mut self, completion: Arc<dyn NonBlockingHandler>) -> Self {
        self.completion = Some(completion);
        self
    }

    fn completion_handler(&self) -> anyhow::Result<&Arc<dyn NonBlockingHandler>> {
        self.completion
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("transport has no completion handler"))
    }

    /// Account and deliver a response body exactly like the response leg
    /// of a blocking `call` — the event runtime's message/byte counters
    /// stay bit-identical to the thread runtime's.
    fn finish_response(&self, path: &str, resp: Value) -> anyhow::Result<Value> {
        let resp_encoded = self.codec.encode(&resp);
        self.stats.record_response(path, resp_encoded.len());
        self.stats.record_codec(self.codec.format(), resp_encoded.len());
        self.charge(resp_encoded.len());
        self.codec.decode(&resp_encoded)
    }

    /// Completion-style request: accounts the request leg (one recorded
    /// message, same as `call`), then probes once. `Ready` carries a fully
    /// accounted response; `Pending` returns the [`PollKey`] to wait on —
    /// subsequent probes via [`InProcTransport::try_complete`] are
    /// server-internal and record nothing, mirroring how a blocked
    /// long-poll re-checks its predicate without new messages.
    pub fn submit(&self, path: &str, body: &Value) -> anyhow::Result<Submitted> {
        let completion = self.completion_handler()?;
        let fault = self.net_draw(path, body);
        let encoded = self.codec.encode(body);
        self.stats.record(path, encoded.len());
        self.stats.record_codec(self.codec.format(), encoded.len());
        self.charge(encoded.len());
        // Request-leg fault: the attempt is counted (the bytes left the
        // NIC) but the handler never runs, exactly like the blocking path.
        self.fault_request(fault.as_ref(), encoded.len())?;
        let decoded = self.codec.decode(&encoded)?;
        match completion.try_handle(path, &decoded) {
            TryHandle::Ready(resp) => {
                // Response-leg fault: only immediate (post) responses are
                // eligible, so parked completions are never dropped.
                self.fault_response(fault.as_ref())?;
                self.sniff_dedup(path, &resp);
                Ok(Submitted::Ready(self.finish_response(path, resp)?))
            }
            TryHandle::WouldBlock(key) => Ok(Submitted::Pending(key)),
        }
    }

    /// Re-probe a pending submission. `Some` completes it (response leg
    /// accounted); `None` means still parked. The codecs round-trip
    /// losslessly (pinned by the codec tests), so probing the original
    /// body is equivalent to re-decoding the recorded request.
    pub fn try_complete(&self, path: &str, body: &Value) -> anyhow::Result<Option<Value>> {
        let completion = self.completion_handler()?;
        match completion.try_handle(path, body) {
            TryHandle::Ready(resp) => Ok(Some(self.finish_response(path, resp)?)),
            TryHandle::WouldBlock(_) => Ok(None),
        }
    }

    /// Complete a pending submission whose poll window expired with the
    /// same `status: "empty"` response (and response-leg accounting) the
    /// blocking server returns at poll timeout.
    pub fn complete_empty(&self, path: &str) -> anyhow::Result<Value> {
        self.finish_response(path, crate::proto::status("empty"))
    }

    /// Forward §5.9 gauge hints to the server (no-ops without completion).
    pub fn notify_parked(&self, path: &str) {
        if let Some(c) = &self.completion {
            c.poll_parked(path);
        }
    }

    /// See [`InProcTransport::notify_parked`].
    pub fn notify_unparked(&self, path: &str) {
        if let Some(c) = &self.completion {
            c.poll_unparked(path);
        }
    }
}

impl InProcTransport {
    fn call_inner(&self, path: &str, body: &Value) -> anyhow::Result<Value> {
        // Faithful to the REST deployment: the body really crosses the
        // configured codec's boundary in both directions (client encode →
        // server decode, and back), so INSEC's big cleartext float arrays
        // pay their true serialization cost — that asymmetry is what
        // drives the paper's Figs 9/12 crossovers.
        let fault = self.net_draw(path, body);
        let encoded = self.codec.encode(body);
        self.stats.record(path, encoded.len());
        self.stats.record_codec(self.codec.format(), encoded.len());
        self.charge(encoded.len());
        self.fault_request(fault.as_ref(), encoded.len())?;
        let decoded = self.codec.decode(&encoded)?;
        let resp = self.handler.handle(path, &decoded);
        self.fault_response(fault.as_ref())?;
        self.sniff_dedup(path, &resp);
        let resp_encoded = self.codec.encode(&resp);
        self.stats.record_response(path, resp_encoded.len());
        self.stats.record_codec(self.codec.format(), resp_encoded.len());
        self.charge(resp_encoded.len());
        self.codec.decode(&resp_encoded)
    }
}

impl ClientTransport for InProcTransport {
    fn call(&self, path: &str, body: &Value) -> anyhow::Result<Value> {
        let started = std::time::Instant::now();
        let resp = self.call_inner(path, body)?;
        self.observe_latency(path, started.elapsed());
        Ok(resp)
    }

    fn message_count(&self) -> u64 {
        self.stats.total()
    }

    fn bytes_sent(&self) -> u64 {
        self.stats.bytes()
    }

    fn bytes_received(&self) -> u64 {
        self.stats.bytes_received()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Handler for Echo {
        fn handle(&self, path: &str, body: &Value) -> Value {
            Value::object(vec![("path", Value::from(path)), ("echo", body.clone())])
        }
    }

    #[test]
    fn inproc_roundtrip_and_counting() {
        let t = InProcTransport::new(Arc::new(Echo));
        let body = Value::object(vec![("x", Value::from(1u64))]);
        let resp = t.call("/post_aggregate", &body).unwrap();
        assert_eq!(resp.str_of("path"), Some("/post_aggregate"));
        assert_eq!(resp.get("echo"), Some(&body));
        assert_eq!(t.message_count(), 1);
        assert!(t.bytes_sent() > 0);
        assert!(t.bytes_received() > 0);
        t.call("/get_average", &body).unwrap();
        assert_eq!(t.message_count(), 2);
        let per = t.stats().per_path();
        assert_eq!(per.get("/post_aggregate"), Some(&1));
        assert_eq!(per.get("/get_average"), Some(&1));
    }

    #[test]
    fn shared_stats_accumulate_across_clients() {
        let stats = Arc::new(MessageStats::default());
        let h: Arc<dyn Handler> = Arc::new(Echo);
        let t1 = InProcTransport::with_shared_stats(h.clone(), stats.clone(), Duration::ZERO);
        let t2 = InProcTransport::with_shared_stats(h, stats.clone(), Duration::ZERO);
        t1.call("/a", &Value::obj()).unwrap();
        t2.call("/a", &Value::obj()).unwrap();
        t2.call("/b", &Value::obj()).unwrap();
        assert_eq!(stats.total(), 3);
        assert_eq!(stats.per_path().get("/a"), Some(&2));
        stats.reset();
        assert_eq!(stats.total(), 0);
        assert_eq!(stats.bytes_received(), 0);
    }

    #[test]
    fn binary_codec_transport_roundtrips_and_counts_codec_bytes() {
        let t = InProcTransport::new(Arc::new(Echo)).with_wire_format(WireFormat::Binary);
        let body = Value::object(vec![(
            "vec",
            Value::from((0..64).map(|i| i as f64 * 0.5 + 0.25).collect::<Vec<f64>>()),
        )]);
        let resp = t.call("/x", &body).unwrap();
        assert_eq!(resp.get("echo"), Some(&body));
        let stats = t.stats();
        assert!(stats.codec_bytes(WireFormat::Binary) > 0);
        assert_eq!(stats.codec_bytes(WireFormat::Json), 0);
    }

    #[test]
    fn json_and_binary_transports_agree_on_responses() {
        let h: Arc<dyn Handler> = Arc::new(Echo);
        let tj = InProcTransport::new(h.clone());
        let tb = InProcTransport::new(h).with_wire_format(WireFormat::Binary);
        // Full-mantissa floats, like real aggregation output (masking
        // noise makes averages ~17 significant digits as JSON text; raw
        // 8-byte f64s only beat decimal text for such vectors).
        let avg: Vec<f64> = (0..48).map(|i| i as f64 * 0.707_106_781_186_547_6 + 0.1).collect();
        let body = Value::object(vec![
            ("avg", Value::from(avg)),
            ("node", Value::from(7u64)),
            ("tag", Value::from("x:y")),
        ]);
        let rj = tj.call("/p", &body).unwrap();
        let rb = tb.call("/p", &body).unwrap();
        assert_eq!(rj, rb);
        // Binary ships fewer bytes for the same message.
        assert!(tb.bytes_sent() < tj.bytes_sent());
    }

    #[test]
    fn per_path_counts_survive_concurrent_recording() {
        let stats = Arc::new(MessageStats::default());
        let threads: Vec<_> = (0..8)
            .map(|i| {
                let stats = stats.clone();
                std::thread::spawn(move || {
                    let path = if i % 2 == 0 { "/even" } else { "/odd" };
                    for _ in 0..100 {
                        stats.record(path, 3);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(stats.total(), 800);
        assert_eq!(stats.per_path().get("/even"), Some(&400));
        assert_eq!(stats.per_path().get("/odd"), Some(&400));
        assert_eq!(stats.bytes(), 2400);
        // Per-path byte totals survive the same concurrency.
        let per = stats.per_path_stats();
        assert_eq!(per.get("/even").unwrap().bytes_sent, 1200);
        assert_eq!(per.get("/odd").unwrap().bytes_sent, 1200);
    }

    #[test]
    fn per_path_stats_track_both_directions() {
        let stats = MessageStats::default();
        stats.record("/post_aggregate", 100);
        stats.record("/post_aggregate", 50);
        stats.record_response("/post_aggregate", 7);
        stats.record("/get_average", 10);
        stats.record_response("/get_average", 900);
        let per = stats.per_path_stats();
        assert_eq!(
            per.get("/post_aggregate"),
            Some(&PathStat { messages: 2, bytes_sent: 150, bytes_received: 7 })
        );
        assert_eq!(
            per.get("/get_average"),
            Some(&PathStat { messages: 1, bytes_sent: 10, bytes_received: 900 })
        );
        assert_eq!(stats.bytes_received(), 907);
        stats.reset();
        assert!(stats.per_path_stats().is_empty());
    }

    #[test]
    fn net_faults_drop_and_delay_deterministically() {
        use crate::proto;
        // A profile that drops every request on faulted paths.
        let p = NetProfile::parse("lan,loss-req=0.9,lat-us=0,jitter-us=0,per-kib-us=0").unwrap();
        let nf = Arc::new(NetFaults::new(NetProfile { loss_request: 0.9, ..p }));
        let t = InProcTransport::new(Arc::new(Echo)).with_net(nf);
        let body = Value::object(vec![("from_node", Value::from(1u64))]);
        let mut lost = 0u64;
        for _ in 0..50 {
            match t.call(proto::POST_AGGREGATE, &body) {
                Err(e) => {
                    assert_eq!(as_transport_error(&e), Some(TransportError::LostRequest));
                    lost += 1;
                }
                Ok(_) => {}
            }
        }
        assert!(lost >= 30, "expected heavy request loss, saw {lost}");
        assert_eq!(t.stats().drops(), lost);
        // Control-plane ops never fault even under total loss.
        for _ in 0..20 {
            t.call(proto::STATUS, &body).unwrap();
        }
        // Request-leg drops still count as sent attempts.
        assert_eq!(t.message_count(), 70);
    }

    #[test]
    fn net_response_loss_hits_posts_after_the_handler_ran() {
        use crate::proto;
        let profile = NetProfile {
            loss_response: 0.9,
            ..NetProfile::parse("lan,lat-us=0,jitter-us=0,per-kib-us=0,loss-req=0").unwrap()
        };
        let nf = Arc::new(NetFaults::new(profile));
        let t = InProcTransport::new(Arc::new(Echo)).with_net(nf);
        let body = Value::object(vec![("from_node", Value::from(2u64))]);
        let mut lost = 0u64;
        for _ in 0..50 {
            if let Err(e) = t.call(proto::POST_AGGREGATE, &body) {
                assert_eq!(as_transport_error(&e), Some(TransportError::LostResponse));
                lost += 1;
            }
        }
        assert!(lost >= 30, "expected heavy response loss, saw {lost}");
        // Consuming long-polls are never response-dropped.
        for _ in 0..50 {
            t.call(proto::GET_AGGREGATE, &body).unwrap();
        }
    }

    #[test]
    fn ideal_net_profile_is_a_byte_for_byte_no_op() {
        let plain = InProcTransport::new(Arc::new(Echo));
        let faulted = InProcTransport::new(Arc::new(Echo))
            .with_net(Arc::new(NetFaults::new(NetProfile::ideal())));
        let body = Value::object(vec![("from_node", Value::from(3u64))]);
        let a = plain.call(crate::proto::POST_AGGREGATE, &body).unwrap();
        let b = faulted.call(crate::proto::POST_AGGREGATE, &body).unwrap();
        assert_eq!(a, b);
        assert_eq!(plain.bytes_sent(), faulted.bytes_sent());
        assert_eq!(faulted.stats().drops(), 0);
    }

    /// Races register / wake / wake_all across many threads and checks
    /// the two WaitHub guarantees the event runtime leans on: no lost
    /// wakeups (every registration that is followed by a wake on its key
    /// is delivered) and no stale-generation deliveries (a delivered
    /// wakeup always carries the generation it was registered with —
    /// filtering of superseded generations is the executor's job, so the
    /// hub must never invent or mangle one).
    #[test]
    fn wait_hub_stress_no_lost_or_stale_wakeups() {
        use std::sync::atomic::AtomicBool;

        struct Recorder {
            seen: Mutex<Vec<(u64, u64)>>,
        }
        impl WakeSink for Recorder {
            fn wake(&self, task: u64, generation: u64) {
                self.seen.lock().unwrap().push((task, generation));
            }
        }

        let hub = Arc::new(WaitHub::default());
        let rec = Arc::new(Recorder { seen: Mutex::new(Vec::new()) });
        hub.set_sink(rec.clone());
        let stop = Arc::new(AtomicBool::new(false));

        // A chaos thread hammers wake/wake_all on every key while the
        // registering threads run.
        let chaos = {
            let hub = hub.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    hub.wake(PollKey::Aggregate { group: i % 4, node: i % 8 });
                    if i % 7 == 0 {
                        hub.wake_all();
                    }
                    i += 1;
                }
            })
        };

        let mut expected = 0u64;
        let workers: Vec<_> = (0..4u64)
            .map(|w| {
                let hub = hub.clone();
                std::thread::spawn(move || {
                    for g in 0..200u64 {
                        let key = PollKey::Aggregate { group: w % 4, node: w % 8 };
                        hub.register(key, w, g);
                        // Ensure delivery even if the chaos thread's wake
                        // raced ahead of this registration.
                        hub.wake(key);
                    }
                })
            })
            .collect();
        expected += 4 * 200;
        for t in workers {
            t.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        chaos.join().unwrap();
        // Final sweep: anything still parked gets delivered.
        hub.wake_all();

        let seen = rec.seen.lock().unwrap();
        // No lost wakeups: every registration was delivered exactly once.
        assert_eq!(seen.len() as u64, expected, "lost or duplicated wakeups");
        // No stale generations: per (task), generations are exactly the
        // registered set 0..200 (order may interleave across keys but a
        // delivery never carries a generation that was not registered).
        for w in 0..4u64 {
            let mut gens: Vec<u64> =
                seen.iter().filter(|(t, _)| *t == w).map(|(_, g)| *g).collect();
            gens.sort_unstable();
            assert_eq!(gens, (0..200u64).collect::<Vec<_>>(), "task {w}");
        }
    }

    #[test]
    fn deflate_transport_roundtrips() {
        let t = InProcTransport::new(Arc::new(Echo))
            .with_wire_format(WireFormat::BinaryDeflate);
        let body = Value::object(vec![
            ("vec", Value::from(vec![1.5f64; 64])),
            ("blob", Value::Bytes(crate::blob::Blob::new(vec![9u8; 256]))),
        ]);
        let resp = t.call("/x", &body).unwrap();
        assert_eq!(resp.get("echo"), Some(&body));
        assert!(t.stats().codec_bytes(WireFormat::BinaryDeflate) > 0);
        assert_eq!(t.stats().codec_bytes(WireFormat::Binary), 0);
    }
}
