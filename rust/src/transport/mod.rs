//! Transport substrate: how learners reach the controller.
//!
//! The paper runs REST/HTTPS between multi-threaded clients and a Flask
//! server (§2, §6). We provide two interchangeable transports behind the
//! same request/response interface:
//!
//! * [`InProcTransport`] — learners call the controller service directly
//!   (one OS thread per learner, exactly like the paper's edge benchmark
//!   where "each learner node is run concurrently in separate threads").
//!   Optionally injects a per-message latency to model the REST hop.
//! * [`http::HttpTransport`] / [`http::HttpServer`] — a from-scratch
//!   HTTP/1.1 client/server over `std::net` (tokio is not in the offline
//!   crate cache), with keep-alive and long-poll friendly blocking
//!   handlers. Used by the integration tests, the `safe` CLI processes and
//!   the hierarchical-federation example.
//!
//! Every call is counted so the benches can verify the paper's message
//! complexity formulas (`4n`, `4n + 2f`, `(i+1)(4n+2f+in)`, `+g`).

pub mod http;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::json::Value;

/// Server-side request handler (the controller implements this).
/// Handlers may block (long-polling `get_*`/`check_*` ops).
pub trait Handler: Send + Sync {
    fn handle(&self, path: &str, body: &Value) -> Value;
}

/// Client-side view of the wire.
pub trait ClientTransport: Send + Sync {
    fn call(&self, path: &str, body: &Value) -> anyhow::Result<Value>;
    /// Messages sent through this transport so far.
    fn message_count(&self) -> u64;
    /// Bytes sent (request bodies) through this transport so far.
    fn bytes_sent(&self) -> u64;
}

/// Per-path message counters shared by the transports.
#[derive(Default)]
pub struct MessageStats {
    total: AtomicU64,
    bytes: AtomicU64,
    per_path: Mutex<BTreeMap<String, u64>>,
}

impl MessageStats {
    pub fn record(&self, path: &str, bytes: usize) {
        self.total.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        let mut map = self.per_path.lock().unwrap();
        *map.entry(path.to_string()).or_insert(0) += 1;
    }

    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn per_path(&self) -> BTreeMap<String, u64> {
        self.per_path.lock().unwrap().clone()
    }

    pub fn reset(&self) {
        self.total.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
        self.per_path.lock().unwrap().clear();
    }
}

/// Direct in-process transport: the default for benchmarks (deterministic,
/// no socket noise) — mirrors the paper's single-machine edge setup.
pub struct InProcTransport {
    handler: Arc<dyn Handler>,
    stats: Arc<MessageStats>,
    /// Simulated one-way network latency applied to each call (the REST
    /// hop the paper's numbers include). Zero by default.
    pub latency: Duration,
    /// Simulated transfer cost per KiB of body (request + response) —
    /// models the REST stack's per-byte handling.
    pub per_kib: Duration,
}

impl InProcTransport {
    pub fn new(handler: Arc<dyn Handler>) -> Self {
        InProcTransport {
            handler,
            stats: Arc::new(MessageStats::default()),
            latency: Duration::ZERO,
            per_kib: Duration::ZERO,
        }
    }

    pub fn with_latency(handler: Arc<dyn Handler>, latency: Duration) -> Self {
        InProcTransport {
            handler,
            stats: Arc::new(MessageStats::default()),
            latency,
            per_kib: Duration::ZERO,
        }
    }

    pub fn with_shared_stats(
        handler: Arc<dyn Handler>,
        stats: Arc<MessageStats>,
        latency: Duration,
    ) -> Self {
        InProcTransport { handler, stats, latency, per_kib: Duration::ZERO }
    }

    /// Full cost model: fixed hop latency + per-KiB transfer cost.
    pub fn with_costs(
        handler: Arc<dyn Handler>,
        stats: Arc<MessageStats>,
        latency: Duration,
        per_kib: Duration,
    ) -> Self {
        InProcTransport { handler, stats, latency, per_kib }
    }

    fn charge(&self, bytes: usize) {
        let mut d = self.latency;
        if !self.per_kib.is_zero() {
            d += self.per_kib.mul_f64(bytes as f64 / 1024.0);
        }
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }

    pub fn stats(&self) -> Arc<MessageStats> {
        self.stats.clone()
    }
}

impl ClientTransport for InProcTransport {
    fn call(&self, path: &str, body: &Value) -> anyhow::Result<Value> {
        // Faithful to the REST deployment: the body really crosses a
        // JSON boundary in both directions (client serialize → server
        // parse, and back), so INSEC's big cleartext float arrays pay
        // their true serialization cost — that asymmetry is what drives
        // the paper's Figs 9/12 crossovers.
        let encoded = body.to_string();
        self.stats.record(path, encoded.len());
        self.charge(encoded.len());
        let decoded = crate::json::parse(&encoded)?;
        let resp = self.handler.handle(path, &decoded);
        let resp_encoded = resp.to_string();
        self.charge(resp_encoded.len());
        crate::json::parse(&resp_encoded)
    }

    fn message_count(&self) -> u64 {
        self.stats.total()
    }

    fn bytes_sent(&self) -> u64 {
        self.stats.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Handler for Echo {
        fn handle(&self, path: &str, body: &Value) -> Value {
            Value::object(vec![("path", Value::from(path)), ("echo", body.clone())])
        }
    }

    #[test]
    fn inproc_roundtrip_and_counting() {
        let t = InProcTransport::new(Arc::new(Echo));
        let body = Value::object(vec![("x", Value::from(1u64))]);
        let resp = t.call("/post_aggregate", &body).unwrap();
        assert_eq!(resp.str_of("path"), Some("/post_aggregate"));
        assert_eq!(resp.get("echo"), Some(&body));
        assert_eq!(t.message_count(), 1);
        assert!(t.bytes_sent() > 0);
        t.call("/get_average", &body).unwrap();
        assert_eq!(t.message_count(), 2);
        let per = t.stats().per_path();
        assert_eq!(per.get("/post_aggregate"), Some(&1));
        assert_eq!(per.get("/get_average"), Some(&1));
    }

    #[test]
    fn shared_stats_accumulate_across_clients() {
        let stats = Arc::new(MessageStats::default());
        let h: Arc<dyn Handler> = Arc::new(Echo);
        let t1 = InProcTransport::with_shared_stats(h.clone(), stats.clone(), Duration::ZERO);
        let t2 = InProcTransport::with_shared_stats(h, stats.clone(), Duration::ZERO);
        t1.call("/a", &Value::obj()).unwrap();
        t2.call("/a", &Value::obj()).unwrap();
        t2.call("/b", &Value::obj()).unwrap();
        assert_eq!(stats.total(), 3);
        assert_eq!(stats.per_path().get("/a"), Some(&2));
        stats.reset();
        assert_eq!(stats.total(), 0);
    }
}
