//! Deterministic network-fault model for the in-proc transport.
//!
//! The paper's numbers are measured on a clean LAN, but SAFE's chain is
//! latency-serial: one lost hop stalls the whole group. [`NetProfile`]
//! grows the transport's single fixed-latency knob into a reproducible
//! hostile-network model — per-leg latency + jitter, bandwidth-
//! proportional delay for large bodies, independent request/response
//! packet loss, and designated straggler nodes — so the §5.3/§5.4
//! failover machinery is exercised against loss and stragglers instead
//! of only scheduled deaths.
//!
//! **Determinism.** Every per-call decision (drop? how much jitter?) is
//! drawn from a ChaCha20 stream keyed by `(profile seed, node id, path
//! hash, per-(node,path) attempt sequence)`. A node's k-th attempt on a
//! path sees the same draw regardless of thread interleaving or which
//! runtime (`threads` / `events`) issued it, so retry/drop counters and
//! round averages are bit-identical across runs and runtimes with the
//! same seed.
//!
//! **Scope.** Faults apply only to the five chain-data ops
//! (`post_aggregate`, `get_aggregate`, `check_aggregate`, `post_average`,
//! `get_average`). Control-plane ops (configure / begin_round /
//! progress_check / status / reset) and the round-0 key exchange ride a
//! reliable control channel — the paper counts setup traffic separately
//! (footnote 3), and faulting the monitor would blind the very failover
//! mechanism under test. Response-leg loss is further restricted to the
//! two post ops: a post is answered immediately in both runtimes and a
//! resend is made safe by the dedup token, whereas losing a consuming
//! long-poll's delivery is indistinguishable from the node dying
//! mid-protocol — a scenario the churn schedules already cover.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::crypto::rng::{DeterministicRng, SecureRng};
use crate::json::Value;
use crate::proto;

/// Upper bound accepted for the per-leg loss probabilities: retries must
/// be able to make progress, so a profile cannot drop everything.
pub const MAX_LOSS: f64 = 0.9;

/// Upper bound accepted for the timing fields (µs): 10 seconds.
pub const MAX_TIMING_US: u64 = 10_000_000;

/// A reproducible per-link network fault model (see module docs).
///
/// The [`Default`] profile is [`NetProfile::ideal`]: byte-for-byte
/// inactive, so every existing exact-count test and bench is unaffected
/// unless a profile is selected explicitly (`--net`).
#[derive(Debug, Clone, PartialEq)]
pub struct NetProfile {
    /// Preset name (or the preset the overrides started from).
    pub name: String,
    /// Base one-way latency applied to each leg of a faulted op. Adds on
    /// top of the device profile's REST-hop cost.
    pub latency: Duration,
    /// Uniform jitter in `[0, jitter)` drawn independently per leg.
    pub jitter: Duration,
    /// Bandwidth-proportional delay per KiB of body on a faulted op.
    pub per_kib: Duration,
    /// Probability the request leg is dropped before the server sees it.
    pub loss_request: f64,
    /// Probability the response leg of a post is dropped after the server
    /// processed it (side effects landed; dedup token makes resend safe).
    pub loss_response: f64,
    /// Every k-th node (`node % k == 0`) is a straggler; 0 disables.
    pub straggler_every: u64,
    /// Latency/jitter multiplier applied to straggler nodes' legs.
    pub straggler_factor: u32,
    /// Seed for the fault stream (independent of the session data seed).
    pub seed: u64,
}

impl Default for NetProfile {
    fn default() -> Self {
        NetProfile::ideal()
    }
}

impl NetProfile {
    fn named(name: &str) -> NetProfile {
        NetProfile {
            name: name.to_string(),
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
            per_kib: Duration::ZERO,
            loss_request: 0.0,
            loss_response: 0.0,
            straggler_every: 0,
            straggler_factor: 1,
            seed: 42,
        }
    }

    /// The no-op profile: no delay, no loss, no stragglers.
    #[must_use]
    pub fn ideal() -> NetProfile {
        NetProfile::named("ideal")
    }

    /// Clean local network: sub-millisecond hops, no loss.
    #[must_use]
    pub fn lan() -> NetProfile {
        NetProfile {
            latency: Duration::from_micros(200),
            jitter: Duration::from_micros(100),
            per_kib: Duration::from_micros(5),
            ..NetProfile::named("lan")
        }
    }

    /// Wide-area link: milliseconds of latency, rare loss.
    #[must_use]
    pub fn wan() -> NetProfile {
        NetProfile {
            latency: Duration::from_millis(2),
            jitter: Duration::from_millis(1),
            per_kib: Duration::from_micros(40),
            loss_request: 0.005,
            loss_response: 0.002,
            ..NetProfile::named("wan")
        }
    }

    /// Cellular link: high latency and jitter, noticeable loss.
    #[must_use]
    pub fn lte() -> NetProfile {
        NetProfile {
            latency: Duration::from_millis(6),
            jitter: Duration::from_millis(4),
            per_kib: Duration::from_micros(80),
            loss_request: 0.02,
            loss_response: 0.01,
            ..NetProfile::named("lte")
        }
    }

    /// Hostile link: heavy loss on both legs, modest latency — the
    /// profile that exercises retry/dedup/failover hardest.
    #[must_use]
    pub fn lossy() -> NetProfile {
        NetProfile {
            latency: Duration::from_micros(500),
            jitter: Duration::from_micros(500),
            per_kib: Duration::from_micros(10),
            loss_request: 0.10,
            loss_response: 0.05,
            ..NetProfile::named("lossy")
        }
    }

    /// LAN timing, but every 7th node is 25x slower — the §5.9
    /// staggered-polling and progress-timeout regime.
    #[must_use]
    pub fn straggler() -> NetProfile {
        NetProfile {
            straggler_every: 7,
            straggler_factor: 25,
            ..NetProfile::lan()
        }
    }

    /// True when the profile injects nothing (transport fast path).
    #[must_use]
    pub fn is_ideal(&self) -> bool {
        self.latency.is_zero()
            && self.jitter.is_zero()
            && self.per_kib.is_zero()
            && self.loss_request == 0.0
            && self.loss_response == 0.0
            && self.straggler_every == 0
    }

    /// Expected round-trip time of one faulted op with a ~1 KiB body:
    /// two legs of base latency plus half the jitter window each, plus
    /// the per-KiB transfer cost. The §6.3 timeout budgets scale from
    /// this instead of hardcoding LAN numbers.
    #[must_use]
    pub fn expected_rtt(&self) -> Duration {
        2 * (self.latency + self.jitter / 2) + self.per_kib
    }

    /// A timeout budget honest under this profile: at least `base`
    /// (the clean-LAN constant), stretched to `rtts` expected RTTs when
    /// the profile is slower than that.
    #[must_use]
    pub fn budget(&self, base: Duration, rtts: u32) -> Duration {
        base.max(self.expected_rtt() * rtts)
    }

    /// The retry policy matched to this profile: 5 attempts with
    /// exponential backoff starting at half an expected RTT (1 ms floor).
    #[must_use]
    pub fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy { attempts: 5, base: (self.expected_rtt() / 2).max(Duration::from_millis(1)) }
    }

    /// Parse a `--net` spec: `PRESET[,FIELD=VALUE]*`.
    ///
    /// Presets: `ideal`, `lan`, `wan`, `lte`, `lossy`, `straggler`.
    /// Fields: `lat-us`, `jitter-us`, `per-kib-us` (µs, `0..=10000000`),
    /// `loss-req`, `loss-resp` (`0.0..=0.9`), `straggler-every` (node
    /// stride, 0 disables), `straggler-x` (`1..=1000`), `seed` (u64).
    ///
    /// ```
    /// use safe_agg::transport::netprofile::NetProfile;
    /// let p = NetProfile::parse("lossy,loss-req=0.2,seed=7").unwrap();
    /// assert_eq!(p.loss_request, 0.2);
    /// assert_eq!(p.seed, 7);
    /// assert!(NetProfile::parse("lan,loss-req=1.5").is_err());
    /// ```
    pub fn parse(spec: &str) -> Result<NetProfile> {
        let spec = spec.trim();
        let mut parts = spec.split(',');
        let preset = parts.next().unwrap_or("").trim();
        let mut profile = match preset {
            "ideal" => NetProfile::ideal(),
            "lan" => NetProfile::lan(),
            "wan" => NetProfile::wan(),
            "lte" => NetProfile::lte(),
            "lossy" => NetProfile::lossy(),
            "straggler" => NetProfile::straggler(),
            other => bail!(
                "net profile {spec:?}: unknown preset {other:?} \
                 (expected ideal|lan|wan|lte|lossy|straggler)"
            ),
        };
        for part in parts {
            let part = part.trim();
            let (key, value) = part
                .split_once('=')
                .with_context(|| format!("net profile override {part:?}: expected FIELD=VALUE"))?;
            let (key, value) = (key.trim(), value.trim());
            match key {
                "lat-us" => profile.latency = Duration::from_micros(parse_timing(key, value)?),
                "jitter-us" => profile.jitter = Duration::from_micros(parse_timing(key, value)?),
                "per-kib-us" => profile.per_kib = Duration::from_micros(parse_timing(key, value)?),
                "loss-req" => profile.loss_request = parse_loss(key, value)?,
                "loss-resp" => profile.loss_response = parse_loss(key, value)?,
                "straggler-every" => {
                    profile.straggler_every = value.parse().with_context(|| {
                        format!("net profile field straggler-every={value}: expected a node stride (u64, 0 disables)")
                    })?;
                }
                "straggler-x" => {
                    let x: u32 = value.parse().with_context(|| {
                        format!("net profile field straggler-x={value}: expected a multiplier within 1..=1000")
                    })?;
                    if !(1..=1000).contains(&x) {
                        bail!("net profile field straggler-x={x}: must be within 1..=1000");
                    }
                    profile.straggler_factor = x;
                }
                "seed" => {
                    profile.seed = value.parse().with_context(|| {
                        format!("net profile field seed={value}: expected a u64")
                    })?;
                }
                other => bail!(
                    "net profile {spec:?}: unknown field {other:?} (known: lat-us, jitter-us, \
                     per-kib-us, loss-req, loss-resp, straggler-every, straggler-x, seed)"
                ),
            }
        }
        Ok(profile)
    }
}

fn parse_timing(key: &str, value: &str) -> Result<u64> {
    let us: u64 = value.parse().with_context(|| {
        format!("net profile field {key}={value}: expected microseconds within 0..={MAX_TIMING_US}")
    })?;
    if us > MAX_TIMING_US {
        bail!("net profile field {key}={us}: must be within 0..={MAX_TIMING_US} (microseconds)");
    }
    Ok(us)
}

fn parse_loss(key: &str, value: &str) -> Result<f64> {
    let p: f64 = value.parse().with_context(|| {
        format!("net profile field {key}={value}: expected a probability within 0.0..={MAX_LOSS}")
    })?;
    if !(0.0..=MAX_LOSS).contains(&p) {
        bail!("net profile field {key}={p}: must be within 0.0..={MAX_LOSS}");
    }
    Ok(p)
}

/// A bounded retry schedule: exponential backoff, 200 ms cap per step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (first try included). 1 disables retries.
    pub attempts: u32,
    /// Backoff before the first retry; doubles each further retry.
    pub base: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        NetProfile::ideal().retry_policy()
    }
}

impl RetryPolicy {
    /// Backoff to wait after failed attempt `attempt` (0-based).
    #[must_use]
    pub fn backoff(&self, attempt: u32) -> Duration {
        let step = self.base.saturating_mul(1u32 << attempt.min(6));
        step.min(Duration::from_millis(200))
    }
}

/// The per-call fault decision for one op: delays per leg plus drop flags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkFault {
    /// Extra delay before the request reaches the server.
    pub request_delay: Duration,
    /// Extra delay before the response reaches the client.
    pub response_delay: Duration,
    /// Drop the request leg (server never runs the handler).
    pub drop_request: bool,
    /// Drop the response leg (handler ran; caller sees an error).
    pub drop_response: bool,
}

/// Ops subject to fault injection (chain data plane).
fn faultable(path: &str) -> bool {
    matches!(
        path,
        proto::POST_AGGREGATE
            | proto::GET_AGGREGATE
            | proto::CHECK_AGGREGATE
            | proto::POST_AVERAGE
            | proto::GET_AVERAGE
    )
}

/// Ops whose response leg may be dropped (immediate, dedup/idempotent).
fn response_loss_eligible(path: &str) -> bool {
    matches!(path, proto::POST_AGGREGATE | proto::POST_AVERAGE)
}

fn fnv1a(path: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in path.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Shared fault-injection state for one session: the profile plus the
/// per-`(node, path)` attempt counters that key the deterministic draws.
/// One instance is shared (`Arc`) by every per-node transport so the
/// counters advance identically regardless of runtime.
pub struct NetFaults {
    profile: NetProfile,
    seqs: Mutex<BTreeMap<(u64, u64), u64>>,
}

impl NetFaults {
    /// Wrap a profile in fresh per-link state.
    #[must_use]
    pub fn new(profile: NetProfile) -> NetFaults {
        NetFaults { profile, seqs: Mutex::new(BTreeMap::new()) }
    }

    /// The profile this state was built from.
    #[must_use]
    pub fn profile(&self) -> &NetProfile {
        &self.profile
    }

    /// Draw the fault decision for one attempt of `path` with `body`.
    ///
    /// `None` means the op is exempt (control plane / key exchange), the
    /// body names no node, or the profile is ideal — the transport takes
    /// its unmodified fast path. Each call advances the `(node, path)`
    /// sequence, so a retry sees a fresh, still-deterministic draw.
    pub fn draw(&self, path: &str, body: &Value) -> Option<LinkFault> {
        if self.profile.is_ideal() || !faultable(path) {
            return None;
        }
        let node = body.u64_of("node").or_else(|| body.u64_of("from_node"))?;
        let phash = fnv1a(path);
        let seq = {
            let mut seqs = self.seqs.lock().unwrap();
            let slot = seqs.entry((node, phash)).or_insert(0);
            let seq = *slot;
            *slot += 1;
            seq
        };
        let mut key = [0u8; 32];
        key[0..8].copy_from_slice(&self.profile.seed.to_le_bytes());
        key[8..16].copy_from_slice(&node.to_le_bytes());
        key[16..24].copy_from_slice(&phash.to_le_bytes());
        key[24..32].copy_from_slice(&seq.to_le_bytes());
        let mut rng = DeterministicRng::from_bytes(&key);
        let u_req = rng.next_f64();
        let u_resp = rng.next_f64();
        let j_req = rng.next_f64();
        let j_resp = rng.next_f64();
        let p = &self.profile;
        let straggle = p.straggler_every > 0 && node % p.straggler_every == 0;
        let mult = if straggle { p.straggler_factor } else { 1 };
        let leg = |j: f64| (p.latency + p.jitter.mul_f64(j)) * mult;
        Some(LinkFault {
            request_delay: leg(j_req),
            response_delay: leg(j_resp),
            drop_request: u_req < p.loss_request,
            drop_response: response_loss_eligible(path) && u_resp < p.loss_response,
        })
    }

    /// Bandwidth-proportional extra delay for a body of `bytes` bytes.
    #[must_use]
    pub fn transfer_delay(&self, bytes: usize) -> Duration {
        if self.profile.per_kib.is_zero() {
            Duration::ZERO
        } else {
            self.profile.per_kib.mul_f64(bytes as f64 / 1024.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn post_body(node: u64) -> Value {
        Value::object(vec![("from_node", Value::from(node))])
    }

    #[test]
    fn ideal_profile_draws_nothing() {
        let nf = NetFaults::new(NetProfile::ideal());
        assert!(nf.draw(proto::POST_AGGREGATE, &post_body(3)).is_none());
        assert!(NetProfile::default().is_ideal());
    }

    #[test]
    fn control_plane_and_key_exchange_are_exempt() {
        let nf = NetFaults::new(NetProfile::lossy());
        let body = Value::object(vec![("node", Value::from(2u64))]);
        assert!(nf.draw(proto::PROGRESS_CHECK, &body).is_none());
        assert!(nf.draw(proto::BEGIN_ROUND, &body).is_none());
        assert!(nf.draw(proto::REGISTER_KEY, &body).is_none());
        assert!(nf.draw(proto::GET_KEY, &body).is_none());
        assert!(nf.draw(proto::GET_AGGREGATE, &body).is_some());
        // A faulted path with no node field is also exempt.
        assert!(nf.draw(proto::GET_AGGREGATE, &Value::obj()).is_none());
    }

    #[test]
    fn draws_are_deterministic_per_node_path_sequence() {
        let a = NetFaults::new(NetProfile::lossy());
        let b = NetFaults::new(NetProfile::lossy());
        for node in 0..8u64 {
            for _ in 0..16 {
                let fa = a.draw(proto::POST_AGGREGATE, &post_body(node));
                let fb = b.draw(proto::POST_AGGREGATE, &post_body(node));
                assert_eq!(fa, fb);
            }
        }
        // Interleaving across nodes does not perturb per-node sequences.
        let c = NetFaults::new(NetProfile::lossy());
        let c0: Vec<_> = (0..16).map(|_| c.draw(proto::POST_AGGREGATE, &post_body(0))).collect();
        let d = NetFaults::new(NetProfile::lossy());
        for i in 0..16 {
            let _ = d.draw(proto::POST_AGGREGATE, &post_body(7)); // interleaved noise
            assert_eq!(d.draw(proto::POST_AGGREGATE, &post_body(0)), c0[i]);
        }
    }

    #[test]
    fn loss_rates_are_roughly_honoured() {
        let nf = NetFaults::new(NetProfile { seed: 9, ..NetProfile::lossy() });
        let mut req_drops = 0;
        let mut resp_drops = 0;
        let trials = 4000;
        for i in 0..trials {
            let f = nf.draw(proto::POST_AGGREGATE, &post_body(i % 5)).unwrap();
            req_drops += u64::from(f.drop_request);
            resp_drops += u64::from(f.drop_response);
        }
        // lossy: 10% request, 5% response. Allow generous slack.
        assert!((200..=600).contains(&req_drops), "req drops {req_drops}");
        assert!((80..=350).contains(&resp_drops), "resp drops {resp_drops}");
        // Consuming long-polls never lose the response leg.
        for i in 0..200 {
            let f = nf.draw(proto::GET_AGGREGATE, &post_body(i % 5)).unwrap();
            assert!(!f.drop_response);
        }
    }

    #[test]
    fn stragglers_are_slower() {
        let p = NetProfile::straggler();
        let nf = NetFaults::new(p.clone());
        let slow = nf.draw(proto::GET_AVERAGE, &post_body(7)).unwrap();
        let fast = nf.draw(proto::GET_AVERAGE, &post_body(8)).unwrap();
        assert!(slow.request_delay >= p.latency * p.straggler_factor);
        assert!(fast.request_delay < p.latency * p.straggler_factor);
    }

    #[test]
    fn parse_presets_and_overrides() {
        assert_eq!(NetProfile::parse("lan").unwrap(), NetProfile::lan());
        assert_eq!(NetProfile::parse("ideal").unwrap(), NetProfile::ideal());
        let p = NetProfile::parse("wan, lat-us=9000, loss-req=0.1, straggler-every=4, straggler-x=10, seed=3").unwrap();
        assert_eq!(p.latency, Duration::from_micros(9000));
        assert_eq!(p.loss_request, 0.1);
        assert_eq!(p.straggler_every, 4);
        assert_eq!(p.straggler_factor, 10);
        assert_eq!(p.seed, 3);
        assert_eq!(p.name, "wan");
    }

    #[test]
    fn parse_errors_name_field_and_range() {
        let e = format!("{:#}", NetProfile::parse("dsl").unwrap_err());
        assert!(e.contains("unknown preset"), "{e}");
        assert!(e.contains("lan|wan|lte|lossy|straggler"), "{e}");
        let e = format!("{:#}", NetProfile::parse("lan,loss-req=1.5").unwrap_err());
        assert!(e.contains("loss-req"), "{e}");
        assert!(e.contains("0.0..=0.9"), "{e}");
        let e = format!("{:#}", NetProfile::parse("lan,lat-us=99999999999").unwrap_err());
        assert!(e.contains("lat-us"), "{e}");
        let e = format!("{:#}", NetProfile::parse("lan,bogus=1").unwrap_err());
        assert!(e.contains("unknown field"), "{e}");
        assert!(e.contains("bogus"), "{e}");
        let e = format!("{:#}", NetProfile::parse("lan,jitter-us").unwrap_err());
        assert!(e.contains("FIELD=VALUE"), "{e}");
        let e = format!("{:#}", NetProfile::parse("lan,straggler-x=0").unwrap_err());
        assert!(e.contains("1..=1000"), "{e}");
    }

    #[test]
    fn rtt_and_budget_scale_with_profile() {
        let ideal = NetProfile::ideal();
        assert_eq!(ideal.expected_rtt(), Duration::ZERO);
        let base = Duration::from_millis(200);
        assert_eq!(ideal.budget(base, 50), base);
        let lte = NetProfile::lte();
        assert!(lte.expected_rtt() >= Duration::from_millis(12));
        assert!(lte.budget(base, 50) > base);
        let policy = lte.retry_policy();
        assert_eq!(policy.attempts, 5);
        assert!(policy.backoff(1) > policy.backoff(0));
        assert!(policy.backoff(20) <= Duration::from_millis(200));
    }
}
