//! Small shared utilities: hex/base64 codecs, timing and stats helpers.
//!
//! These exist as substrates because the offline crate cache has neither
//! `hex` nor `base64`; the SAFE wire format (JSON, like the paper's Flask
//! controller) carries ciphertexts as base64 strings.

use std::io::{Read, Write};
use std::time::{Duration, Instant};

use flate2::read::DeflateDecoder;
use flate2::write::DeflateEncoder;
use flate2::Compression;

/// DEFLATE-compress a byte buffer (shared by the §5.7 payload envelope and
/// the `proto::codec::CompressedCodec` wire wrapper).
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(data).expect("in-memory deflate cannot fail");
    enc.finish().expect("in-memory deflate cannot fail")
}

/// Decompression-bomb guard: refuse to inflate beyond this many bytes.
/// The HTTP server's request-body cap checks only the *compressed* size,
/// so without this limit a tiny deflate bomb could expand to gigabytes
/// inside the codec layer. 64 MiB comfortably covers the largest real
/// message (a 100k-feature JSON average is ~2 MiB) while bounding the
/// amplification a thread-per-connection server can be made to allocate.
pub const MAX_DECOMPRESSED: usize = 64 << 20;

/// Inverse of [`compress`]. Output is capped at [`MAX_DECOMPRESSED`].
pub fn decompress(data: &[u8]) -> anyhow::Result<Vec<u8>> {
    use anyhow::Context;
    let dec = DeflateDecoder::new(data);
    let mut out = Vec::new();
    let mut limited = dec.take(MAX_DECOMPRESSED as u64 + 1);
    limited.read_to_end(&mut out).context("deflate decompression failed")?;
    if out.len() > MAX_DECOMPRESSED {
        anyhow::bail!("decompressed body exceeds {MAX_DECOMPRESSED} bytes");
    }
    Ok(out)
}

/// LEB128 varint encode — the one shared implementation (binary codec
/// field lengths and the envelope's blob framing both use it).
pub fn write_varint(mut n: u64, out: &mut Vec<u8>) {
    loop {
        let b = (n & 0x7f) as u8;
        n >>= 7;
        if n == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// LEB128 varint decode from `bytes` starting at `*pos`, advancing `*pos`
/// past the varint. Rejects overlong and u64-overflowing encodings.
pub fn read_varint(bytes: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    let mut n = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *bytes
            .get(*pos)
            .ok_or_else(|| anyhow::anyhow!("truncated varint"))?;
        *pos += 1;
        if shift >= 63 && b > 1 {
            anyhow::bail!("varint overflows u64");
        }
        n |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(n);
        }
        shift += 7;
        if shift > 63 {
            anyhow::bail!("varint too long");
        }
    }
}

/// Encoded size of [`write_varint`]'s output for `n`.
pub fn varint_len(mut n: u64) -> usize {
    let mut len = 1;
    while n >= 0x80 {
        n >>= 7;
        len += 1;
    }
    len
}

/// Encode bytes as lowercase hex.
pub fn hex_encode(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(HEX[(b >> 4) as usize] as char);
        out.push(HEX[(b & 0xf) as usize] as char);
    }
    out
}

/// Decode a hex string (case-insensitive). Errors on odd length or bad digit.
pub fn hex_decode(s: &str) -> anyhow::Result<Vec<u8>> {
    let s = s.trim();
    if s.len() % 2 != 0 {
        anyhow::bail!("hex string has odd length {}", s.len());
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in bytes.chunks_exact(2) {
        let hi = hex_val(pair[0])?;
        let lo = hex_val(pair[1])?;
        out.push((hi << 4) | lo);
    }
    Ok(out)
}

fn hex_val(c: u8) -> anyhow::Result<u8> {
    match c {
        b'0'..=b'9' => Ok(c - b'0'),
        b'a'..=b'f' => Ok(c - b'a' + 10),
        b'A'..=b'F' => Ok(c - b'A' + 10),
        _ => anyhow::bail!("invalid hex digit {:?}", c as char),
    }
}

const B64_ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard base64 (RFC 4648, with padding).
pub fn b64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity((data.len() + 2) / 3 * 4);
    for chunk in data.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = *chunk.get(1).unwrap_or(&0) as u32;
        let b2 = *chunk.get(2).unwrap_or(&0) as u32;
        let n = (b0 << 16) | (b1 << 8) | b2;
        out.push(B64_ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(B64_ALPHABET[(n >> 12) as usize & 63] as char);
        if chunk.len() > 1 {
            out.push(B64_ALPHABET[(n >> 6) as usize & 63] as char);
        } else {
            out.push('=');
        }
        if chunk.len() > 2 {
            out.push(B64_ALPHABET[n as usize & 63] as char);
        } else {
            out.push('=');
        }
    }
    out
}

/// Decode standard base64 (padding optional, whitespace ignored).
pub fn b64_decode(s: &str) -> anyhow::Result<Vec<u8>> {
    fn val(c: u8) -> anyhow::Result<u32> {
        match c {
            b'A'..=b'Z' => Ok((c - b'A') as u32),
            b'a'..=b'z' => Ok((c - b'a') as u32 + 26),
            b'0'..=b'9' => Ok((c - b'0') as u32 + 52),
            b'+' => Ok(62),
            b'/' => Ok(63),
            _ => anyhow::bail!("invalid base64 character {:?}", c as char),
        }
    }
    let mut out = Vec::with_capacity(s.len() / 4 * 3);
    let mut acc: u32 = 0;
    let mut nbits = 0u32;
    for &c in s.as_bytes() {
        if c == b'=' || c.is_ascii_whitespace() {
            continue;
        }
        acc = (acc << 6) | val(c)?;
        nbits += 6;
        if nbits >= 8 {
            nbits -= 8;
            out.push((acc >> nbits) as u8);
        }
    }
    Ok(out)
}

/// A simple stopwatch around `Instant` used throughout the benches.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrip() {
        let data = vec![0u8, 1, 127, 128, 255, 16, 32];
        let h = hex_encode(&data);
        assert_eq!(h, "00017f80ff1020");
        assert_eq!(hex_decode(&h).unwrap(), data);
    }

    #[test]
    fn hex_rejects_bad_input() {
        assert!(hex_decode("abc").is_err());
        assert!(hex_decode("zz").is_err());
    }

    #[test]
    fn b64_known_vectors() {
        // RFC 4648 §10 test vectors.
        assert_eq!(b64_encode(b""), "");
        assert_eq!(b64_encode(b"f"), "Zg==");
        assert_eq!(b64_encode(b"fo"), "Zm8=");
        assert_eq!(b64_encode(b"foo"), "Zm9v");
        assert_eq!(b64_encode(b"foob"), "Zm9vYg==");
        assert_eq!(b64_encode(b"fooba"), "Zm9vYmE=");
        assert_eq!(b64_encode(b"foobar"), "Zm9vYmFy");
    }

    #[test]
    fn b64_roundtrip_all_bytes() {
        let data: Vec<u8> = (0..=255u8).collect();
        assert_eq!(b64_decode(&b64_encode(&data)).unwrap(), data);
    }

    #[test]
    fn b64_rejects_garbage() {
        assert!(b64_decode("$$$$").is_err());
    }

    #[test]
    fn varint_roundtrip_and_len() {
        for n in [0u64, 1, 127, 128, 16383, 16384, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(n, &mut buf);
            assert_eq!(buf.len(), varint_len(n), "len for {n}");
            let mut pos = 0usize;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), n);
            assert_eq!(pos, buf.len());
        }
        // Truncated and overlong encodings are rejected.
        assert!(read_varint(&[0x80], &mut 0).is_err());
        assert!(read_varint(&[0xff; 11], &mut 0).is_err());
    }

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }
}
