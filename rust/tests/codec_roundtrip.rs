//! Wire-codec properties: `decode ∘ encode = id` for every typed proto
//! message — including the blob-carrying ones — under all four codec
//! stacks (json, binary, json+deflate, binary+deflate), plus cross-codec
//! session equivalence (same seeded SAFE round under every stack →
//! identical averages and message counts, with the expected byte
//! orderings) and the controller's zero-copy pass-through guarantee.

use std::collections::BTreeMap;
use std::time::Duration;

use safe_agg::blob::Blob;
use safe_agg::config::{DeviceProfile, SessionConfig, WireFormat};
use safe_agg::crypto::rng::{DeterministicRng, SecureRng};
use safe_agg::json::Value;
use safe_agg::learner::faults::FaultPlan;
use safe_agg::proto;
use safe_agg::proto::codec::{BinaryCodec, JsonCodec, WireCodec};
use safe_agg::protocols::SafeSession;
use safe_agg::testkit::{self, gen};
use safe_agg::util::b64_encode;

/// Push `v` through every codec stack and assert each roundtrips to
/// identity. (`Value` equality bridges `Bytes` and its base64 `Str`
/// rendering, so this holds for blob-carrying messages on JSON wires too.)
fn value_roundtrips(v: &Value) -> bool {
    WireFormat::ALL.iter().all(|fmt| {
        let codec = fmt.codec();
        let dec = codec.decode(&codec.encode(v)).expect(fmt.name());
        dec == *v
    })
}

/// Decode `msg.to_value()` back through codec `fmt` into a typed message.
fn reparse(fmt: WireFormat, v: &Value) -> Value {
    let codec = fmt.codec();
    codec.decode(&codec.encode(v)).unwrap()
}

fn b64_blob(rng: &mut DeterministicRng, max_len: usize) -> String {
    b64_encode(&gen::bytes(rng, max_len))
}

fn blob(rng: &mut DeterministicRng, max_len: usize) -> Blob {
    Blob::new(gen::bytes(rng, max_len))
}

#[test]
fn prop_post_aggregate_roundtrip() {
    testkit::check(
        "codec-post-aggregate",
        60,
        |rng| proto::PostAggregate {
            from_node: rng.next_below(1000) as u64,
            to_node: rng.next_below(1000) as u64,
            group: 1 + rng.next_below(8) as u64,
            aggregate: blob(rng, 2000),
            round_id: if rng.next_below(2) == 0 { None } else { Some(rng.next_u64() >> 40) },
            epoch: if rng.next_below(2) == 0 { None } else { Some(rng.next_u64() >> 48) },
            token: if rng.next_below(2) == 0 { None } else { Some(rng.next_u64() >> 32) },
        },
        |msg| {
            let v = msg.to_value();
            value_roundtrips(&v)
                && WireFormat::ALL.iter().all(|&fmt| {
                    proto::PostAggregate::from_value(&reparse(fmt, &v)).unwrap() == *msg
                })
        },
    );
}

#[test]
fn prop_node_op_and_decisions_roundtrip() {
    testkit::check(
        "codec-node-op",
        60,
        |rng| {
            (
                proto::NodeOp::new(rng.next_u64() >> 40, 1 + rng.next_below(8) as u64),
                proto::InitiateDecision {
                    init: rng.next_below(2) == 0,
                    round_id: rng.next_below(100) as u64,
                },
                if rng.next_below(2) == 0 {
                    proto::CheckOutcome::Consumed
                } else {
                    proto::CheckOutcome::Repost { to_node: rng.next_below(100) as u64 }
                },
            )
        },
        |(op, dec, chk)| {
            let (ov, dv, cv) = (op.to_value(), dec.to_value(), chk.to_value());
            value_roundtrips(&ov)
                && value_roundtrips(&dv)
                && value_roundtrips(&cv)
                && proto::NodeOp::from_value(&reparse(WireFormat::Binary, &ov)).unwrap() == *op
                && proto::InitiateDecision::from_value(&reparse(WireFormat::Binary, &dv))
                    .unwrap()
                    == *dec
                && proto::CheckOutcome::from_value(&reparse(WireFormat::Binary, &cv)).unwrap()
                    == *chk
        },
    );
}

#[test]
fn prop_averages_roundtrip() {
    testkit::check(
        "codec-averages",
        60,
        |rng| {
            let avg = gen::f64_vec(rng, 256);
            (
                proto::PostAverage {
                    node: 1 + rng.next_below(50) as u64,
                    group: 1 + rng.next_below(4) as u64,
                    average: avg.clone(),
                    contributors: 1 + rng.next_below(50) as u64,
                },
                proto::AverageReady { average: avg.clone(), groups: 1 + rng.next_below(4) as u64 },
                proto::AggregateDelivery {
                    aggregate: blob(rng, 500),
                    from_node: rng.next_below(50) as u64,
                    posted: Some(rng.next_below(50) as u64),
                    round_id: Some(rng.next_below(10) as u64),
                },
            )
        },
        |(pa, ar, del)| {
            let (pv, av, dv) = (pa.to_value(), ar.to_value(), del.to_value());
            value_roundtrips(&pv)
                && value_roundtrips(&av)
                && value_roundtrips(&dv)
                && proto::PostAverage::from_value(&reparse(WireFormat::Binary, &pv)).unwrap()
                    == *pa
                && proto::AverageReady::from_value(&reparse(WireFormat::Binary, &av)).unwrap()
                    == *ar
                && WireFormat::ALL.iter().all(|&fmt| {
                    proto::AggregateDelivery::from_value(&reparse(fmt, &dv)).unwrap() == *del
                })
        },
    );
}

#[test]
fn prop_key_registry_roundtrip() {
    testkit::check(
        "codec-key-registry",
        40,
        |rng| {
            let key = Value::object(vec![
                ("n", Value::from(b64_blob(rng, 128))),
                ("e", Value::from("10001")),
            ]);
            let mut keys = BTreeMap::new();
            for peer in 1..=(1 + rng.next_below(5) as u64) {
                keys.insert(peer, blob(rng, 64));
            }
            (
                proto::RegisterKey { node: 1 + rng.next_below(100) as u64, key: key.clone() },
                proto::GetKey { node: 1 + rng.next_below(100) as u64 },
                proto::KeyDelivery { key },
                proto::PostPrenegKeys { node: 1 + rng.next_below(100) as u64, keys },
                proto::GetPrenegKey {
                    node: 1 + rng.next_below(100) as u64,
                    owner: 1 + rng.next_below(100) as u64,
                },
                proto::PrenegKeyDelivery { key: blob(rng, 64) },
            )
        },
        |(reg, get, del, post, getp, delp)| {
            for v in [
                reg.to_value(),
                get.to_value(),
                del.to_value(),
                post.to_value(),
                getp.to_value(),
                delp.to_value(),
            ] {
                if !value_roundtrips(&v) {
                    return false;
                }
            }
            proto::RegisterKey::from_value(&reparse(WireFormat::Binary, &reg.to_value()))
                .unwrap()
                == *reg
                && WireFormat::ALL.iter().all(|&fmt| {
                    proto::PostPrenegKeys::from_value(&reparse(fmt, &post.to_value())).unwrap()
                        == *post
                        && proto::PrenegKeyDelivery::from_value(&reparse(fmt, &delp.to_value()))
                            .unwrap()
                            == *delp
                })
        },
    );
}

#[test]
fn prop_baseline_ops_roundtrip() {
    testkit::check(
        "codec-baseline-ops",
        40,
        |rng| {
            (
                proto::InsecPost {
                    node: 1 + rng.next_below(100) as u64,
                    group: 1 + rng.next_below(4) as u64,
                    vector: gen::f64_vec(rng, 128),
                },
                proto::FedChildAverage {
                    child: 1 + rng.next_below(10) as u64,
                    average: gen::f64_vec(rng, 64),
                    contributors: 1 + rng.next_below(20) as u64,
                },
                proto::FedGlobalAverage {
                    average: gen::f64_vec(rng, 64),
                    contributors: 1 + rng.next_below(100) as u64,
                },
                proto::BonAdvertise {
                    node: 1 + rng.next_below(100) as u64,
                    cpk: b64_blob(rng, 96),
                    spk: b64_blob(rng, 96),
                },
                proto::BonPostMasked {
                    node: 1 + rng.next_below(100) as u64,
                    y: gen::f64_vec(rng, 128),
                },
            )
        },
        |(insec, fca, fga, adv, masked)| {
            let checks = [
                insec.to_value(),
                fca.to_value(),
                fga.to_value(),
                adv.to_value(),
                masked.to_value(),
            ];
            if !checks.iter().all(value_roundtrips) {
                return false;
            }
            proto::InsecPost::from_value(&reparse(WireFormat::Binary, &insec.to_value()))
                .unwrap()
                == *insec
                && proto::BonPostMasked::from_value(&reparse(
                    WireFormat::Binary,
                    &masked.to_value(),
                ))
                .unwrap()
                    == *masked
        },
    );
}

#[test]
fn prop_arbitrary_values_roundtrip_all_codecs() {
    // Beyond the typed messages: any message-model value the system could
    // ever put on the wire must survive every codec stack.
    testkit::check(
        "codec-arbitrary-values",
        80,
        |rng| random_value(rng, 3),
        value_roundtrips,
    );
}

fn random_value(rng: &mut DeterministicRng, depth: usize) -> Value {
    match rng.next_below(if depth == 0 { 6 } else { 8 }) {
        0 => Value::Null,
        1 => Value::Bool(rng.next_below(2) == 0),
        2 => Value::Num((rng.next_f64() - 0.5) * 1e6),
        3 => Value::Num(rng.next_below(100_000) as f64),
        4 => Value::Str(gen::ascii_string(rng, 40)),
        5 => Value::Bytes(Blob::new(gen::bytes(rng, 64))),
        6 => Value::Arr((0..rng.next_below(6)).map(|_| random_value(rng, depth - 1)).collect()),
        _ => {
            let mut obj = Value::obj();
            for i in 0..rng.next_below(6) {
                obj.set(&format!("k{i}"), random_value(rng, depth - 1));
            }
            obj
        }
    }
}

// ---------------------------------------------------------------------
// Cross-codec session equivalence + wire-size acceptance
// ---------------------------------------------------------------------

fn session_cfg(wire: WireFormat, features: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: 4,
        features,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        poll_time: Duration::from_secs(5),
        aggregation_timeout: Duration::from_secs(60),
        // Generous failure thresholds: a descheduled learner thread on a
        // loaded CI box must never trigger a repost, or the sessions'
        // message counts would legitimately diverge.
        progress_timeout: Duration::from_secs(30),
        monitor_interval: Duration::from_millis(200),
        wire,
        ..Default::default()
    }
}

fn inputs(n: usize, features: usize) -> Vec<Vec<f64>> {
    // Full-mantissa values, like real model weights — their JSON text is
    // ~17 significant digits, the regime the binary codec targets.
    (1..=n)
        .map(|i| {
            (0..features)
                .map(|f| i as f64 * 1.25 + f as f64 * 0.707_106_781_186_547_6)
                .collect()
        })
        .collect()
}

#[test]
fn cross_codec_rounds_are_equivalent_across_all_stacks() {
    let features = 1024;
    let ins = inputs(4, features);

    // One seeded session per codec stack; identical protocol behaviour.
    let mut totals: BTreeMap<&'static str, u64> = BTreeMap::new();
    let mut reference: Option<(Vec<f64>, u64, BTreeMap<String, u64>)> = None;
    let mut binary_total = 0u64;
    let mut binary_agg_blob_traffic = 0u64;
    for fmt in WireFormat::ALL {
        let session = SafeSession::new(session_cfg(fmt, features)).unwrap();
        let before = session.stats().per_path_stats();
        let round = session.run_round(&ins, &FaultPlan::none()).unwrap();
        let after = session.stats().per_path_stats();

        // All codec traffic must be attributed to this session's stack.
        assert!(session.stats().codec_bytes(fmt) > 0, "{}", fmt.name());
        for other in WireFormat::ALL {
            if other != fmt {
                assert_eq!(
                    session.stats().codec_bytes(other),
                    0,
                    "{} leaked into {}",
                    fmt.name(),
                    other.name()
                );
            }
        }

        let avg = round.average().unwrap().to_vec();
        if let Some((ref_avg, ref_msgs, ref_paths)) = &reference {
            assert_eq!(avg.len(), ref_avg.len());
            for (a, b) in avg.iter().zip(ref_avg.iter()) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{}: averages must be byte-identical",
                    fmt.name()
                );
            }
            assert_eq!(round.metrics.messages, *ref_msgs, "{}", fmt.name());
            assert_eq!(&round.metrics.per_path, ref_paths, "{}", fmt.name());
        } else {
            reference = Some((avg, round.metrics.messages, round.metrics.per_path.clone()));
        }
        let total = round.metrics.bytes_sent + round.metrics.bytes_received;
        totals.insert(fmt.name(), total);
        if fmt == WireFormat::Binary {
            binary_total = total;
            // Blob-dominated aggregate-path traffic this round: what PR 1's
            // binary codec carried as base64 text.
            let delta = |path: &str, f: fn(&safe_agg::transport::PathStat) -> u64| {
                f(after.get(path).unwrap())
                    - before.get(path).map_or(0, |s| f(s))
            };
            binary_agg_blob_traffic = delta("/post_aggregate", |s| s.bytes_sent)
                + delta("/get_aggregate", |s| s.bytes_received);
        }
    }

    let json = totals["json"];
    let binary = totals["binary"];
    let json_deflate = totals["json+deflate"];
    let binary_deflate = totals["binary+deflate"];
    // Raw framing beats JSON, and deflate beats bare JSON (decimal floats
    // and base64 text are highly compressible).
    assert!(binary < json, "binary {binary} must beat json {json}");
    assert!(json_deflate < json, "json+deflate {json_deflate} must beat json {json}");
    assert!(
        binary_deflate < json,
        "binary+deflate {binary_deflate} must beat json {json}"
    );
    // The acceptance bar: binary+deflate ships strictly fewer bytes than
    // PR 1's binary codec. PR 1 carried every aggregate blob as base64
    // text inside a string field — ≥ 1/3 extra on the blob bytes. A
    // conservative floor for PR 1's total (discounting per-message
    // non-blob framing generously) still exceeds today's binary+deflate.
    assert!(binary_agg_blob_traffic > 0, "no aggregate traffic measured");
    let pr1_binary_floor = binary_total + binary_agg_blob_traffic.saturating_sub(1024) / 4;
    assert!(
        binary_deflate < pr1_binary_floor,
        "binary+deflate {binary_deflate} must beat PR 1's binary (≥ {pr1_binary_floor})"
    );
}

#[test]
fn binary_strictly_smaller_on_hot_paths_at_1024_features() {
    // post_aggregate / post_average messages for ≥1024-feature vectors
    // must be strictly smaller under BinaryCodec — and the raw blob
    // framing must undercut PR 1's base64-text framing by ≥ 25% on the
    // aggregate path.
    let mut rng = DeterministicRng::seed(99);
    let mut payload = vec![0u8; 1024 * 8];
    rng.fill_bytes(&mut payload);
    let env = safe_agg::crypto::envelope::Envelope {
        mode: safe_agg::crypto::envelope::CipherMode::Hybrid,
        sealed_key: payload[..64].to_vec(),
        body: payload.clone(),
    };
    let post_agg = proto::PostAggregate {
        from_node: 3,
        to_node: 4,
        group: 1,
        aggregate: env.to_blob(),
        round_id: Some(0),
        epoch: None,
        token: None,
    }
    .to_value();
    // PR 1's shape: the same envelope as `mode:keyB64:bodyB64` text.
    let pr1_post_agg = Value::object(vec![
        ("aggregate", Value::from(env.encode())),
        ("from_node", Value::from(3u64)),
        ("group", Value::from(1u64)),
        ("round_id", Value::from(0u64)),
        ("to_node", Value::from(4u64)),
    ]);
    let avg: Vec<f64> = (0..1024).map(|i| (i as f64) * 0.3711 + 0.017).collect();
    let post_avg = proto::PostAverage { node: 1, group: 1, average: avg, contributors: 4 }
        .to_value();
    for (label, msg) in [("post_aggregate", &post_agg), ("post_average", &post_avg)] {
        let b = BinaryCodec.encode(msg).len();
        let j = JsonCodec.encode(msg).len();
        assert!(b < j, "{label}: binary {b} must be < json {j}");
    }
    // Whole-message comparison: strictly smaller than PR 1's framing.
    let new_msg = BinaryCodec.encode(&post_agg).len();
    let pr1_msg = BinaryCodec.encode(&pr1_post_agg).len();
    assert!(new_msg < pr1_msg, "raw framing {new_msg} must beat PR 1's {pr1_msg}");
    // Aggregate-path bytes (the framed aggregate field itself): the raw
    // blob must undercut PR 1's base64-text framing by ≥ 25%.
    let new_field = BinaryCodec.encode(&Value::Bytes(env.to_blob())).len();
    let pr1_field = BinaryCodec.encode(&Value::from(env.encode())).len();
    assert!(
        new_field * 4 <= pr1_field * 3,
        "raw framing {new_field} must be ≥25% below PR 1's base64 framing {pr1_field}"
    );
}
