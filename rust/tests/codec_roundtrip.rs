//! Wire-codec properties: `decode ∘ encode = id` for every typed proto
//! message under both codecs, plus cross-codec session equivalence (same
//! seeded SAFE round over JSON and binary → identical averages and
//! message counts, strictly fewer binary bytes).

use std::collections::BTreeMap;
use std::time::Duration;

use safe_agg::config::{DeviceProfile, SessionConfig, WireFormat};
use safe_agg::crypto::rng::{DeterministicRng, SecureRng};
use safe_agg::json::Value;
use safe_agg::learner::faults::FaultPlan;
use safe_agg::proto;
use safe_agg::proto::codec::{BinaryCodec, JsonCodec, WireCodec};
use safe_agg::protocols::SafeSession;
use safe_agg::testkit::{self, gen};
use safe_agg::util::b64_encode;

/// Push `v` through both codecs and assert each roundtrips to identity.
fn value_roundtrips(v: &Value) -> bool {
    let bin = BinaryCodec.decode(&BinaryCodec.encode(v)).expect("binary decode");
    let json = JsonCodec.decode(&JsonCodec.encode(v)).expect("json decode");
    bin == *v && json == *v
}

fn b64_blob(rng: &mut DeterministicRng, max_len: usize) -> String {
    b64_encode(&gen::bytes(rng, max_len))
}

#[test]
fn prop_post_aggregate_roundtrip() {
    testkit::check(
        "codec-post-aggregate",
        60,
        |rng| proto::PostAggregate {
            from_node: rng.next_below(1000) as u64,
            to_node: rng.next_below(1000) as u64,
            group: 1 + rng.next_below(8) as u64,
            aggregate: format!("safe:{}:{}", b64_blob(rng, 64), b64_blob(rng, 2000)),
            round_id: if rng.next_below(2) == 0 { None } else { Some(rng.next_u64() >> 40) },
        },
        |msg| {
            let v = msg.to_value();
            value_roundtrips(&v)
                && proto::PostAggregate::from_value(
                    &BinaryCodec.decode(&BinaryCodec.encode(&v)).unwrap(),
                )
                .unwrap()
                    == *msg
        },
    );
}

#[test]
fn prop_node_op_and_decisions_roundtrip() {
    testkit::check(
        "codec-node-op",
        60,
        |rng| {
            (
                proto::NodeOp::new(rng.next_u64() >> 40, 1 + rng.next_below(8) as u64),
                proto::InitiateDecision {
                    init: rng.next_below(2) == 0,
                    round_id: rng.next_below(100) as u64,
                },
                if rng.next_below(2) == 0 {
                    proto::CheckOutcome::Consumed
                } else {
                    proto::CheckOutcome::Repost { to_node: rng.next_below(100) as u64 }
                },
            )
        },
        |(op, dec, chk)| {
            let (ov, dv, cv) = (op.to_value(), dec.to_value(), chk.to_value());
            value_roundtrips(&ov)
                && value_roundtrips(&dv)
                && value_roundtrips(&cv)
                && proto::NodeOp::from_value(&BinaryCodec.decode(&BinaryCodec.encode(&ov)).unwrap())
                    .unwrap()
                    == *op
                && proto::InitiateDecision::from_value(
                    &BinaryCodec.decode(&BinaryCodec.encode(&dv)).unwrap(),
                )
                .unwrap()
                    == *dec
                && proto::CheckOutcome::from_value(
                    &BinaryCodec.decode(&BinaryCodec.encode(&cv)).unwrap(),
                )
                .unwrap()
                    == *chk
        },
    );
}

#[test]
fn prop_averages_roundtrip() {
    testkit::check(
        "codec-averages",
        60,
        |rng| {
            let avg = gen::f64_vec(rng, 256);
            (
                proto::PostAverage {
                    node: 1 + rng.next_below(50) as u64,
                    group: 1 + rng.next_below(4) as u64,
                    average: avg.clone(),
                    contributors: 1 + rng.next_below(50) as u64,
                },
                proto::AverageReady { average: avg.clone(), groups: 1 + rng.next_below(4) as u64 },
                proto::AggregateDelivery {
                    aggregate: b64_blob(rng, 500),
                    from_node: rng.next_below(50) as u64,
                    posted: Some(rng.next_below(50) as u64),
                    round_id: Some(rng.next_below(10) as u64),
                },
            )
        },
        |(pa, ar, del)| {
            let (pv, av, dv) = (pa.to_value(), ar.to_value(), del.to_value());
            value_roundtrips(&pv)
                && value_roundtrips(&av)
                && value_roundtrips(&dv)
                && proto::PostAverage::from_value(
                    &BinaryCodec.decode(&BinaryCodec.encode(&pv)).unwrap(),
                )
                .unwrap()
                    == *pa
                && proto::AverageReady::from_value(
                    &BinaryCodec.decode(&BinaryCodec.encode(&av)).unwrap(),
                )
                .unwrap()
                    == *ar
                && proto::AggregateDelivery::from_value(
                    &BinaryCodec.decode(&BinaryCodec.encode(&dv)).unwrap(),
                )
                .unwrap()
                    == *del
        },
    );
}

#[test]
fn prop_key_registry_roundtrip() {
    testkit::check(
        "codec-key-registry",
        40,
        |rng| {
            let key = Value::object(vec![
                ("n", Value::from(b64_blob(rng, 128))),
                ("e", Value::from("10001")),
            ]);
            let mut keys = BTreeMap::new();
            for peer in 1..=(1 + rng.next_below(5) as u64) {
                keys.insert(peer, b64_blob(rng, 64));
            }
            (
                proto::RegisterKey { node: 1 + rng.next_below(100) as u64, key: key.clone() },
                proto::GetKey { node: 1 + rng.next_below(100) as u64 },
                proto::KeyDelivery { key },
                proto::PostPrenegKeys { node: 1 + rng.next_below(100) as u64, keys },
                proto::GetPrenegKey {
                    node: 1 + rng.next_below(100) as u64,
                    owner: 1 + rng.next_below(100) as u64,
                },
                proto::PrenegKeyDelivery { key: b64_blob(rng, 64) },
            )
        },
        |(reg, get, del, post, getp, delp)| {
            for v in [
                reg.to_value(),
                get.to_value(),
                del.to_value(),
                post.to_value(),
                getp.to_value(),
                delp.to_value(),
            ] {
                if !value_roundtrips(&v) {
                    return false;
                }
            }
            proto::RegisterKey::from_value(
                &BinaryCodec.decode(&BinaryCodec.encode(&reg.to_value())).unwrap(),
            )
            .unwrap()
                == *reg
                && proto::PostPrenegKeys::from_value(
                    &BinaryCodec.decode(&BinaryCodec.encode(&post.to_value())).unwrap(),
                )
                .unwrap()
                    == *post
        },
    );
}

#[test]
fn prop_baseline_ops_roundtrip() {
    testkit::check(
        "codec-baseline-ops",
        40,
        |rng| {
            (
                proto::InsecPost {
                    node: 1 + rng.next_below(100) as u64,
                    group: 1 + rng.next_below(4) as u64,
                    vector: gen::f64_vec(rng, 128),
                },
                proto::FedChildAverage {
                    child: 1 + rng.next_below(10) as u64,
                    average: gen::f64_vec(rng, 64),
                    contributors: 1 + rng.next_below(20) as u64,
                },
                proto::FedGlobalAverage {
                    average: gen::f64_vec(rng, 64),
                    contributors: 1 + rng.next_below(100) as u64,
                },
                proto::BonAdvertise {
                    node: 1 + rng.next_below(100) as u64,
                    cpk: b64_blob(rng, 96),
                    spk: b64_blob(rng, 96),
                },
                proto::BonPostMasked {
                    node: 1 + rng.next_below(100) as u64,
                    y: gen::f64_vec(rng, 128),
                },
            )
        },
        |(insec, fca, fga, adv, masked)| {
            let checks = [
                insec.to_value(),
                fca.to_value(),
                fga.to_value(),
                adv.to_value(),
                masked.to_value(),
            ];
            if !checks.iter().all(value_roundtrips) {
                return false;
            }
            proto::InsecPost::from_value(
                &BinaryCodec.decode(&BinaryCodec.encode(&insec.to_value())).unwrap(),
            )
            .unwrap()
                == *insec
                && proto::BonPostMasked::from_value(
                    &BinaryCodec.decode(&BinaryCodec.encode(&masked.to_value())).unwrap(),
                )
                .unwrap()
                    == *masked
        },
    );
}

#[test]
fn prop_arbitrary_values_roundtrip_binary() {
    // Beyond the typed messages: any JSON-model value the system could
    // ever put on the wire must survive the binary codec.
    testkit::check(
        "codec-arbitrary-values",
        80,
        |rng| random_value(rng, 3),
        value_roundtrips,
    );
}

fn random_value(rng: &mut DeterministicRng, depth: usize) -> Value {
    match rng.next_below(if depth == 0 { 5 } else { 7 }) {
        0 => Value::Null,
        1 => Value::Bool(rng.next_below(2) == 0),
        2 => Value::Num((rng.next_f64() - 0.5) * 1e6),
        3 => Value::Num(rng.next_below(100_000) as f64),
        4 => Value::Str(gen::ascii_string(rng, 40)),
        5 => Value::Arr((0..rng.next_below(6)).map(|_| random_value(rng, depth - 1)).collect()),
        _ => {
            let mut obj = Value::obj();
            for i in 0..rng.next_below(6) {
                obj.set(&format!("k{i}"), random_value(rng, depth - 1));
            }
            obj
        }
    }
}

// ---------------------------------------------------------------------
// Cross-codec session equivalence + wire-size acceptance
// ---------------------------------------------------------------------

fn session_cfg(wire: WireFormat, features: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: 4,
        features,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        poll_time: Duration::from_secs(5),
        aggregation_timeout: Duration::from_secs(60),
        // Generous failure thresholds: a descheduled learner thread on a
        // loaded CI box must never trigger a repost, or the two sessions'
        // message counts would legitimately diverge.
        progress_timeout: Duration::from_secs(30),
        monitor_interval: Duration::from_millis(200),
        wire,
        ..Default::default()
    }
}

fn inputs(n: usize, features: usize) -> Vec<Vec<f64>> {
    // Full-mantissa values, like real model weights — their JSON text is
    // ~17 significant digits, the regime the binary codec targets.
    (1..=n)
        .map(|i| {
            (0..features)
                .map(|f| i as f64 * 1.25 + f as f64 * 0.707_106_781_186_547_6)
                .collect()
        })
        .collect()
}

#[test]
fn cross_codec_rounds_are_equivalent() {
    let features = 1024;
    let ins = inputs(4, features);

    let json_session = SafeSession::new(session_cfg(WireFormat::Json, features)).unwrap();
    let json_round = json_session.run_round(&ins, &FaultPlan::none()).unwrap();

    let bin_session = SafeSession::new(session_cfg(WireFormat::Binary, features)).unwrap();
    let bin_round = bin_session.run_round(&ins, &FaultPlan::none()).unwrap();

    // Byte-identical averages.
    let ja = json_round.average().unwrap();
    let ba = bin_round.average().unwrap();
    assert_eq!(ja.len(), ba.len());
    for (a, b) in ja.iter().zip(ba) {
        assert_eq!(a.to_bits(), b.to_bits(), "averages must be byte-identical");
    }
    // Identical message counts (the protocol is codec-agnostic).
    assert_eq!(json_round.metrics.messages, bin_round.metrics.messages);
    assert_eq!(json_round.metrics.per_path, bin_round.metrics.per_path);
    // Binary ships strictly fewer bytes in both directions.
    assert!(
        bin_round.metrics.bytes_sent < json_round.metrics.bytes_sent,
        "binary sent {} vs json {}",
        bin_round.metrics.bytes_sent,
        json_round.metrics.bytes_sent
    );
    assert!(
        bin_round.metrics.bytes_received < json_round.metrics.bytes_received,
        "binary recv {} vs json {}",
        bin_round.metrics.bytes_received,
        json_round.metrics.bytes_received
    );
    // Per-codec accounting matches the direction each session used.
    assert_eq!(json_session.stats().codec_bytes(WireFormat::Binary), 0);
    assert_eq!(bin_session.stats().codec_bytes(WireFormat::Json), 0);
    assert!(bin_session.stats().codec_bytes(WireFormat::Binary) > 0);
}

#[test]
fn binary_strictly_smaller_on_hot_paths_at_1024_features() {
    // The acceptance bullet: post_aggregate / post_average messages for
    // ≥1024-feature vectors must be strictly smaller under BinaryCodec.
    let mut rng = DeterministicRng::seed(99);
    let mut payload = vec![0u8; 1024 * 8];
    rng.fill_bytes(&mut payload);
    let post_agg = proto::PostAggregate {
        from_node: 3,
        to_node: 4,
        group: 1,
        aggregate: format!("safe:{}:{}", b64_encode(&payload[..64]), b64_encode(&payload)),
        round_id: Some(0),
    }
    .to_value();
    let avg: Vec<f64> = (0..1024).map(|i| (i as f64) * 0.3711 + 0.017).collect();
    let post_avg = proto::PostAverage { node: 1, group: 1, average: avg, contributors: 4 }
        .to_value();
    for (label, msg) in [("post_aggregate", &post_agg), ("post_average", &post_avg)] {
        let b = BinaryCodec.encode(msg).len();
        let j = JsonCodec.encode(msg).len();
        assert!(b < j, "{label}: binary {b} must be < json {j}");
    }
}
