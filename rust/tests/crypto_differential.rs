//! Cross-backend differential suite: the gate in front of every bigint
//! backend (ISSUE 8).
//!
//! Strategy: the `Big` trait provides *canonical* randomness (identical
//! byte-stream decoding on every backend), so two same-seeded
//! `DeterministicRng`s drive the native backend (u64 limbs, Karatsuba,
//! Montgomery fixed-window modexp) and the vendored reference backend
//! (u32 limbs, schoolbook, binary modexp) through the same value
//! sequences — and every operation must come back byte-identical. With
//! the algorithms deliberately disjoint, agreement at every width is
//! strong evidence both are right; divergence pinpoints the width and
//! operation that broke.
//!
//! Seeded ChaCha20 only — no `rand` dependency, fully reproducible.

use std::cmp::Ordering;

use safe_agg::crypto::backend::{Big, ModContext, NativeBig};
use safe_agg::crypto::bigint_dig::DigBig;
use safe_agg::crypto::dh::{DhGroup, DhKeyPair, MODP_2048_HEX};
use safe_agg::crypto::prime;
use safe_agg::crypto::rng::DeterministicRng;
use safe_agg::crypto::rsa::RsaKeyPair;
use safe_agg::crypto::shamir;

type N = <NativeBig as Big>::Num;
type D = <DigBig as Big>::Num;

/// Operand widths in bits: limb boundaries for both backends (64 = 1×u64
/// = 2×u32; 65/127 straddle), plus the production sizes (512-bit test
/// RSA, 1024-bit bench RSA, 2048-bit MODP group).
const WIDTHS: &[usize] = &[64, 65, 127, 128, 256, 512, 1024, 2048];

/// Paired deterministic draws: same seed, two backends, one value.
struct Pairs {
    rn: DeterministicRng,
    rd: DeterministicRng,
}

impl Pairs {
    fn new(seed: u64) -> Pairs {
        Pairs { rn: DeterministicRng::seed(seed), rd: DeterministicRng::seed(seed) }
    }

    fn bits(&mut self, bits: usize) -> (N, D) {
        let a = NativeBig::random_bits(bits, &mut self.rn);
        let b = DigBig::random_bits(bits, &mut self.rd);
        assert_same("paired draw", bits, &a, &b);
        (a, b)
    }

    fn below(&mut self, bound: &(N, D)) -> (N, D) {
        let a = NativeBig::random_below(&bound.0, &mut self.rn);
        let b = DigBig::random_below(&bound.1, &mut self.rd);
        assert_same("paired draw", NativeBig::bit_length(&bound.0), &a, &b);
        (a, b)
    }
}

fn assert_same(label: &str, bits: usize, a: &N, b: &D) {
    assert_eq!(
        NativeBig::to_bytes_be(a),
        DigBig::to_bytes_be(b),
        "{label} diverged at {bits} bits"
    );
}

/// Force both sides of a pair to the requested parity with the same
/// arithmetic (so they stay the same value).
fn with_parity(pair: (N, D), even: bool) -> (N, D) {
    let (mut a, mut b) = pair;
    if NativeBig::is_even(&a) != even {
        a = NativeBig::add_u64(&a, 1);
        b = DigBig::add_u64(&b, 1);
    }
    (a, b)
}

#[test]
fn add_sub_mul_div_mod_differential() {
    let mut draw = Pairs::new(0xd1ff);
    for &bits in WIDTHS {
        let a = draw.bits(bits);
        let b = draw.bits(bits / 2 + 1); // strictly smaller: sub is safe
        assert_same("add", bits, &NativeBig::add(&a.0, &b.0), &DigBig::add(&a.1, &b.1));
        assert_same("sub", bits, &NativeBig::sub(&a.0, &b.0), &DigBig::sub(&a.1, &b.1));
        assert_same("mul", bits, &NativeBig::mul(&a.0, &b.0), &DigBig::mul(&a.1, &b.1));
        let (qn, rn) = NativeBig::div_rem(&a.0, &b.0);
        let (qd, rd) = DigBig::div_rem(&a.1, &b.1);
        assert_same("div quotient", bits, &qn, &qd);
        assert_same("div remainder", bits, &rn, &rd);
        // q·b + r reassembles a on both sides.
        assert_same(
            "div reassembly",
            bits,
            &NativeBig::add(&NativeBig::mul(&qn, &b.0), &rn),
            &a.1,
        );
        assert_same("rem", bits, &NativeBig::rem(&a.0, &b.0), &DigBig::rem(&a.1, &b.1));
        let (qn64, rn64) = NativeBig::div_rem_u64(&a.0, 0xfff1);
        let (qd64, rd64) = DigBig::div_rem_u64(&a.1, 0xfff1);
        assert_same("div_rem_u64 quotient", bits, &qn64, &qd64);
        assert_eq!(rn64, rd64, "div_rem_u64 remainder diverged at {bits} bits");
        // Representation round-trips agree too.
        assert_eq!(
            NativeBig::to_hex(&a.0),
            DigBig::to_hex(&a.1),
            "hex encoding diverged at {bits} bits"
        );
        assert_eq!(NativeBig::bit_length(&a.0), DigBig::bit_length(&a.1));
        for i in [0usize, 1, bits / 2, bits - 1] {
            assert_eq!(NativeBig::bit(&a.0, i), DigBig::bit(&a.1, i), "bit {i} at {bits}");
        }
    }
}

#[test]
fn modpow_montgomery_vs_schoolbook_every_width() {
    // Odd moduli put the native backend on its Montgomery fixed-window
    // path while the reference backend stays on schoolbook square-and-
    // multiply — so this is Montgomery-vs-schoolbook at every width.
    // Even moduli exercise the native plain fallback as well.
    let mut draw = Pairs::new(0x6d0d);
    for &bits in WIDTHS {
        for even in [false, true] {
            let m = with_parity(draw.bits(bits), even);
            let base = draw.below(&m);
            let exp = draw.bits(bits.min(128));
            let native = NativeBig::modpow(&base.0, &exp.0, &m.0);
            let dig = DigBig::modpow(&base.1, &exp.1, &m.1);
            assert_same(if even { "modpow (even m)" } else { "modpow (odd m)" }, bits, &native, &dig);
            // The reusable contexts must match their one-shot forms.
            let nctx = NativeBig::ctx(&m.0);
            let dctx = DigBig::ctx(&m.1);
            assert_eq!(nctx.modpow(&base.0, &exp.0), native, "native ctx at {bits}");
            assert_eq!(dctx.modpow(&base.1, &exp.1), dig, "dig ctx at {bits}");
            // Batched form: base^(e·2) both ways.
            let two = (NativeBig::from_u64(2), DigBig::from_u64(2));
            assert_same(
                "modpow_product",
                bits,
                &NativeBig::modpow_product(&base.0, [&exp.0, &two.0], &m.0),
                &DigBig::modpow_product(&base.1, [&exp.1, &two.1], &m.1),
            );
        }
    }
}

#[test]
fn modinv_and_gcd_differential() {
    let mut draw = Pairs::new(0x16cd);
    for &bits in WIDTHS {
        let m = with_parity(draw.bits(bits), false);
        let a = draw.below(&m);
        assert_same("gcd", bits, &NativeBig::gcd(&a.0, &m.0), &DigBig::gcd(&a.1, &m.1));
        let ni = NativeBig::modinv(&a.0, &m.0);
        let di = DigBig::modinv(&a.1, &m.1);
        assert_eq!(ni.is_some(), di.is_some(), "modinv existence diverged at {bits} bits");
        if let (Some(ni), Some(di)) = (ni, di) {
            assert_same("modinv", bits, &ni, &di);
            assert!(NativeBig::is_one(&NativeBig::mulmod(&a.0, &ni, &m.0)));
            assert!(DigBig::is_one(&DigBig::mulmod(&a.1, &di, &m.1)));
        }
    }
}

/// The textbook RSA known-answer test (p=61, q=53, n=3233, e=17,
/// d=2753): encrypt(65) = 65^17 mod 3233 = 2790, decrypt(2790) = 65.
/// Externally computable by hand; run on the raw modpow of each backend.
fn rsa_textbook_kat_on<B: Big>() {
    let n = B::from_u64(3233);
    let c = B::modpow(&B::from_u64(65), &B::from_u64(17), &n);
    assert_eq!(B::as_u64(&c), Some(2790), "{} textbook encrypt", B::NAME);
    let m = B::modpow(&c, &B::from_u64(2753), &n);
    assert_eq!(B::as_u64(&m), Some(65), "{} textbook decrypt", B::NAME);
}

#[test]
fn rsa_textbook_kat_both_backends() {
    rsa_textbook_kat_on::<NativeBig>();
    rsa_textbook_kat_on::<DigBig>();
}

#[test]
fn rsa_keygen_byte_stable_across_backends() {
    // The pinned keygen regression: a fixed seed yields byte-identical
    // keys on every backend (the canonical-randomness + documented
    // RNG-draw-order contract). Any reordering of keygen's RNG
    // consumption, on either backend, trips this.
    let mut rn = DeterministicRng::seed(4242);
    let mut rd = DeterministicRng::seed(4242);
    let kn = RsaKeyPair::<NativeBig>::generate(256, &mut rn);
    let kd = RsaKeyPair::<DigBig>::generate(256, &mut rd);
    assert_same("keygen n", 256, &kn.public.n, &kd.public.n);
    assert_same("keygen d", 256, &kn.private.d, &kd.private.d);
    assert_same("keygen p", 256, &kn.private.p, &kd.private.p);
    assert_same("keygen q", 256, &kn.private.q, &kd.private.q);
    assert_same("keygen qinv", 256, &kn.private.qinv, &kd.private.qinv);
    assert_eq!(NativeBig::as_u64(&kn.public.e), Some(65537));
    assert_eq!(NativeBig::bit_length(&kn.public.n), 256);
    // And keygen itself is a pure function of the seed.
    let again = RsaKeyPair::<NativeBig>::generate(256, &mut DeterministicRng::seed(4242));
    assert_eq!(again.public.n, kn.public.n);
    assert_eq!(again.private.d, kn.private.d);
}

#[test]
fn rsa_encrypt_sign_byte_identical_across_backends() {
    let kn = RsaKeyPair::<NativeBig>::generate(256, &mut DeterministicRng::seed(4242));
    let kd = RsaKeyPair::<DigBig>::generate(256, &mut DeterministicRng::seed(4242));
    // Same keys + same padding RNG ⇒ the exact same ciphertext bytes.
    let msg = b"differential rsa";
    let cn = kn.public.encrypt_block(msg, &mut DeterministicRng::seed(7)).unwrap();
    let cd = kd.public.encrypt_block(msg, &mut DeterministicRng::seed(7)).unwrap();
    assert_eq!(cn, cd, "ciphertext bytes diverged");
    assert_eq!(kn.private.decrypt_block(&cn).unwrap(), msg);
    assert_eq!(kd.private.decrypt_block(&cd).unwrap(), msg);
    // Signatures are deterministic: byte-identical and cross-verifiable.
    let digest = [0xabu8; 32];
    let sn = kn.private.sign_digest(&digest).unwrap();
    let sd = kd.private.sign_digest(&digest).unwrap();
    assert_eq!(sn, sd, "signature bytes diverged");
    assert!(kn.public.verify_digest(&digest, &sd));
    assert!(kd.public.verify_digest(&digest, &sn));
}

/// Textbook DH known-answer test (p=23, g=5, a=6, b=15): A=8, B=19,
/// shared secret 2 on both sides.
fn dh_textbook_kat_on<B: Big>() {
    let p = B::from_u64(23);
    let g = B::from_u64(5);
    let big_a = B::modpow(&g, &B::from_u64(6), &p);
    let big_b = B::modpow(&g, &B::from_u64(15), &p);
    assert_eq!(B::as_u64(&big_a), Some(8), "{} A", B::NAME);
    assert_eq!(B::as_u64(&big_b), Some(19), "{} B", B::NAME);
    let ctx = B::ctx(&p);
    let s1 = ctx.modpow(&big_b, &B::from_u64(6));
    let s2 = ctx.modpow(&big_a, &B::from_u64(15));
    assert_eq!(B::as_u64(&s1), Some(2), "{} shared", B::NAME);
    assert_eq!(s1, s2);
}

#[test]
fn dh_textbook_kat_both_backends() {
    dh_textbook_kat_on::<NativeBig>();
    dh_textbook_kat_on::<DigBig>();
}

#[test]
fn dh_group14_fixture() {
    // RFC 3526 group 14: 2048-bit safe prime, leading and trailing 64
    // bits all ones. Both backends must parse the constant to the same
    // value and round-trip it.
    let pn = NativeBig::from_hex(MODP_2048_HEX).unwrap();
    let pd = DigBig::from_hex(MODP_2048_HEX).unwrap();
    assert_same("group-14 prime", 2048, &pn, &pd);
    assert_eq!(NativeBig::bit_length(&pn), 2048);
    assert!(!NativeBig::is_even(&pn));
    let bytes = NativeBig::to_bytes_be(&pn);
    assert_eq!(bytes.len(), 256);
    assert!(bytes[..8].iter().all(|&b| b == 0xff), "2^2048 - 2^1984 prefix");
    assert!(bytes[248..].iter().all(|&b| b == 0xff), "…FFFFFFFF FFFFFFFF tail");
    assert!(NativeBig::to_hex(&pn).eq_ignore_ascii_case(MODP_2048_HEX));
    // Algebraic cross-check on the group context: (g²)³ = g⁶ mod p.
    let g = NativeBig::from_u64(2);
    let ctx = NativeBig::ctx(&pn);
    let lhs = ctx.modpow(&ctx.modpow(&g, &NativeBig::from_u64(2)), &NativeBig::from_u64(3));
    let rhs = ctx.modpow(&g, &NativeBig::from_u64(6));
    assert_eq!(lhs, rhs);
    // Full key agreement over the standard group, byte-stable across
    // backends under the same seeds.
    let gn = DhGroup::<NativeBig>::standard();
    let gd = DhGroup::<DigBig>::standard();
    let ctxn = gn.ctx();
    let ctxd = gd.ctx();
    let an = DhKeyPair::generate_with(&ctxn, &gn, &mut DeterministicRng::seed(31));
    let ad = DhKeyPair::generate_with(&ctxd, &gd, &mut DeterministicRng::seed(31));
    assert_same("dh public", 2048, &an.public, &ad.public);
    let bn = DhKeyPair::generate_with(&ctxn, &gn, &mut DeterministicRng::seed(32));
    let bd = DhKeyPair::generate_with(&ctxd, &gd, &mut DeterministicRng::seed(32));
    let sn = an.agree_with(&ctxn, &bn.public);
    let sd = ad.agree_with(&ctxd, &bd.public);
    assert_eq!(sn, sd, "KDF output diverged");
    assert_eq!(sn, bn.agree_with(&ctxn, &an.public), "agreement asymmetric");
}

#[test]
fn prime_generation_differential() {
    // Same seed ⇒ the same prime, bit for bit, on both backends (gen
    // draws only through the canonical trait randomness).
    let pn = prime::gen_prime::<NativeBig>(128, &mut DeterministicRng::seed(91));
    let pd = prime::gen_prime::<DigBig>(128, &mut DeterministicRng::seed(91));
    assert_same("generated prime", 128, &pn, &pd);
    assert_eq!(NativeBig::bit_length(&pn), 128);
    // Miller–Rabin verdicts agree on knowns: primes, composites, and
    // Carmichael numbers (the case trial division alone would miss).
    for (v, want) in [
        (2147483647u64, true),        // 2^31 - 1
        (2305843009213693951, true),  // 2^61 - 1
        (561, false),                 // Carmichael
        (41041, false),               // Carmichael
        (2305843009213693953, false), // 2^61 + 1, divisible by 3
    ] {
        let n = prime::is_probable_prime::<NativeBig>(
            &NativeBig::from_u64(v),
            32,
            &mut DeterministicRng::seed(v),
        );
        let d = prime::is_probable_prime::<DigBig>(
            &DigBig::from_u64(v),
            32,
            &mut DeterministicRng::seed(v),
        );
        assert_eq!(n, want, "native verdict for {v}");
        assert_eq!(d, want, "dig verdict for {v}");
    }
}

#[test]
fn shamir_reconstruction_differential() {
    let secret: Vec<u8> = (0u8..48).map(|i| i.wrapping_mul(37) ^ 0x5c).collect();
    let xs: Vec<u64> = (1..=6).collect();
    let mut rng = DeterministicRng::seed(77);
    let shares = shamir::share_secret(&secret, 4, &xs, &mut rng).unwrap();
    // u64-field fast path and both backends' full-bignum Lagrange paths
    // must reconstruct the identical secret from the same quorum.
    let quorum = &shares[1..5];
    assert_eq!(shamir::reconstruct_secret(quorum).unwrap(), secret);
    assert_eq!(shamir::reconstruct_secret_via::<NativeBig>(quorum).unwrap(), secret);
    assert_eq!(shamir::reconstruct_secret_via::<DigBig>(quorum).unwrap(), secret);
    // Redundancy-checked path: clean shares pass, a corrupted redundant
    // share is detected — identically through the checked front-end.
    assert_eq!(shamir::reconstruct_secret_checked(&shares, 4).unwrap(), secret);
    let mut bad = shares.clone();
    bad[5].ys[0] ^= 1;
    assert!(shamir::reconstruct_secret_checked(&bad, 4).is_err());
}

#[test]
fn representation_boundaries_differential() {
    // Zero, one, u64 max, and single-bit values at limb boundaries.
    for v in [0u64, 1, 2, u32::MAX as u64, u32::MAX as u64 + 1, u64::MAX] {
        let a = NativeBig::from_u64(v);
        let b = DigBig::from_u64(v);
        assert_same("u64 roundtrip", 64, &a, &b);
        assert_eq!(NativeBig::as_u64(&a), Some(v));
        assert_eq!(DigBig::as_u64(&b), Some(v));
        assert_eq!(NativeBig::is_zero(&a), v == 0);
        assert_eq!(DigBig::is_zero(&b), v == 0);
        assert_eq!(NativeBig::is_even(&a), DigBig::is_even(&b));
    }
    for &bits in WIDTHS {
        // 2^bits (one past the draw width) through bytes on both sides.
        let mut bytes = vec![0u8; bits / 8 + 1];
        bytes[0] = 1 << (bits % 8);
        let a = NativeBig::from_bytes_be(&bytes);
        let b = DigBig::from_bytes_be(&bytes);
        assert_eq!(NativeBig::bit_length(&a), bits + 1);
        assert_eq!(DigBig::bit_length(&b), bits + 1);
        assert_same("2^bits", bits, &a, &b);
        assert_eq!(
            NativeBig::cmp(&a, &NativeBig::add_u64(&NativeBig::zero(), 1)),
            Ordering::Greater
        );
        // Leading-zero bytes must normalize away identically.
        let mut padded = vec![0u8; 7];
        padded.extend_from_slice(&bytes);
        assert_eq!(NativeBig::from_bytes_be(&padded), a);
        assert_eq!(DigBig::from_bytes_be(&padded), b);
    }
}
