//! Failure-injection matrix: every fault point × position combination the
//! protocol must survive (§5.3/§5.4), plus multi-failure and adjacent-
//! failure cases the paper calls out as the hard ones — and the
//! multi-round churn matrix (die in round r / rejoin in round r+k /
//! die-rejoin-die) that the session engine must survive with correct
//! per-round averages, per-round failover counts, and no key re-exchange
//! for surviving nodes.

use std::time::Duration;

use safe_agg::config::{DeviceProfile, SessionConfig};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::learner::faults::{ChurnSchedule, FailPoint, FaultPlan};
use safe_agg::proto;
use safe_agg::protocols::{SafeRoundResult, SafeSession};

fn cfg(n: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        features: 2,
        mode: CipherMode::Hybrid,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        poll_time: Duration::from_millis(120),
        aggregation_timeout: Duration::from_secs(2),
        progress_timeout: Duration::from_millis(400),
        monitor_interval: Duration::from_millis(60),
        ..Default::default()
    }
}

fn inputs(n: usize) -> Vec<Vec<f64>> {
    (1..=n).map(|i| vec![i as f64, 10.0 * i as f64]).collect()
}

fn expect_mean(n: usize, dead: &[u64]) -> f64 {
    let alive: Vec<f64> = (1..=n as u64)
        .filter(|i| !dead.contains(i))
        .map(|i| i as f64)
        .collect();
    alive.iter().sum::<f64>() / alive.len() as f64
}

fn run_case(n: usize, faults: FaultPlan, dead_contributors: &[u64]) {
    let session = SafeSession::new(cfg(n)).unwrap();
    let result = session.run_round(&inputs(n), &faults).unwrap();
    let expect = expect_mean(n, dead_contributors);
    assert!(
        (result.average().unwrap()[0] - expect).abs() < 1e-6,
        "n={n} faults={faults:?}: got {} want {expect}",
        result.average().unwrap()[0]
    );
    assert_eq!(
        result.metrics.contributors,
        (n - dead_contributors.len()) as u64,
        "contributor count for {faults:?}"
    );
}

#[test]
fn single_failure_every_noninitiator_position() {
    // A node at each non-initiator position dies before starting.
    for pos in 2..=6u64 {
        run_case(6, FaultPlan::none().kill(pos, FailPoint::NeverStart), &[pos]);
    }
}

#[test]
fn failure_after_get_is_recovered() {
    // The hard case from §5.3: the mailbox was already drained when the
    // node died, so the monitor must reconstruct the stuck link from the
    // poster set.
    for pos in 2..=5u64 {
        run_case(6, FaultPlan::none().kill(pos, FailPoint::AfterGet), &[pos]);
    }
}

#[test]
fn failure_after_post_keeps_contribution() {
    // Dying after posting: the value IS in the aggregate; only the dead
    // node misses the result. Average must cover all n nodes.
    let n = 5;
    let session = SafeSession::new(cfg(n)).unwrap();
    let faults = FaultPlan::none().kill(3, FailPoint::AfterPost);
    let result = session.run_round(&inputs(n), &faults).unwrap();
    let expect = (1..=5).sum::<i32>() as f64 / 5.0;
    assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
    assert_eq!(result.metrics.contributors, 5);
    // The dead node has no average; survivors do.
    assert_eq!(result.survivors().len(), 4);
}

#[test]
fn two_adjacent_failures() {
    // §5.3 explicitly worries about "two nodes next to each other on the
    // chain fail simultaneously".
    run_case(
        7,
        FaultPlan::none()
            .kill(3, FailPoint::NeverStart)
            .kill(4, FailPoint::NeverStart),
        &[3, 4],
    );
}

#[test]
fn three_failures_spread_out() {
    run_case(
        9,
        FaultPlan::none()
            .kill(2, FailPoint::NeverStart)
            .kill(5, FailPoint::AfterGet)
            .kill(8, FailPoint::NeverStart),
        &[2, 5, 8],
    );
}

#[test]
fn last_node_failure() {
    // The failed node is the one that would close the loop back to the
    // initiator — repost must wrap around the chain end.
    run_case(5, FaultPlan::none().kill(5, FailPoint::NeverStart), &[5]);
}

#[test]
fn initiator_crash_recovers_with_new_initiator() {
    let n = 5;
    let session = SafeSession::new(cfg(n)).unwrap();
    let faults = FaultPlan::none().kill(1, FailPoint::InitiatorAfterPost);
    let result = session.run_round(&inputs(n), &faults).unwrap();
    assert!(result.metrics.initiator_failovers >= 1);
    let expect = (2 + 3 + 4 + 5) as f64 / 4.0;
    assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
    let new_init = result
        .outcomes
        .iter()
        .find(|o| !o.died && o.was_initiator)
        .unwrap()
        .node;
    assert_ne!(new_init, 1);
}

#[test]
fn initiator_crash_plus_noninitiator_failure() {
    // Compound: the initiator dies AND node 4 never starts.
    let n = 6;
    let session = SafeSession::new(cfg(n)).unwrap();
    let faults = FaultPlan::none()
        .kill(1, FailPoint::InitiatorAfterPost)
        .kill(4, FailPoint::NeverStart);
    let result = session.run_round(&inputs(n), &faults).unwrap();
    let expect = (2 + 3 + 5 + 6) as f64 / 4.0;
    assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
    assert_eq!(result.metrics.contributors, 4);
}

// ---- multi-round churn matrix (SafeSession::run_rounds) ----

/// Churn tests assert exact `4n`-family message counts, which only hold
/// when long polls never retry — so the poll budget is generous (§5.2's
/// "one REST call = one message" accounting).
fn churn_cfg(n: usize) -> SessionConfig {
    SessionConfig { poll_time: Duration::from_secs(5), ..cfg(n) }
}

fn run_churn(n: usize, rounds: usize, churn: &ChurnSchedule) -> Vec<SafeRoundResult> {
    let session = SafeSession::new(churn_cfg(n)).unwrap();
    let per_round: Vec<Vec<Vec<f64>>> = (0..rounds).map(|_| inputs(n)).collect();
    session.run_rounds(&per_round, churn).unwrap()
}

fn assert_round_mean(results: &[SafeRoundResult], round: usize, n: usize, dead: &[u64]) {
    let got = results[round - 1].average().unwrap()[0];
    let want = expect_mean(n, dead);
    assert!(
        (got - want).abs() < 1e-6,
        "round {round}: got {got} want {want} (dead {dead:?})"
    );
    assert_eq!(
        results[round - 1].metrics.contributors,
        (n - dead.len()) as u64,
        "round {round} contributors"
    );
}

/// No key traffic at all in a round (keys were exchanged once and reused).
fn assert_no_key_traffic(r: &SafeRoundResult, round: usize) {
    assert_eq!(r.metrics.rekey_messages, 0, "round {round} rekey count");
    for path in [
        proto::REGISTER_KEY,
        proto::GET_KEY,
        proto::POST_PRENEG_KEYS,
        proto::GET_PRENEG_KEY,
    ] {
        assert!(
            !r.metrics.per_path.contains_key(path),
            "round {round}: survivors' keys must not be re-exchanged ({path})"
        );
    }
}

#[test]
fn churn_die_round1_rejoin_round3() {
    // The acceptance scenario: node 4 dies in round 1, the chain re-forms
    // without it in round 2, and it returns (with a re-key for it alone)
    // in round 3.
    let n = 6;
    let churn = ChurnSchedule::none().die(4, 1, FailPoint::NeverStart).rejoin(4, 3);
    let results = run_churn(n, 4, &churn);
    assert_eq!(results.len(), 4);
    assert_round_mean(&results, 1, n, &[4]);
    assert_round_mean(&results, 2, n, &[4]);
    assert_round_mean(&results, 3, n, &[]);
    assert_round_mean(&results, 4, n, &[]);
    // Round 1 pays the in-round failover; round 2's re-formed chain is
    // failure-free and back to the 4n floor.
    assert_eq!(results[0].metrics.progress_failovers, 1);
    assert_eq!(results[0].metrics.messages, 4 * 5 + 2);
    assert_eq!(results[1].metrics.progress_failovers, 0);
    assert_eq!(results[1].metrics.messages, 4 * 5);
    // Rounds without a rejoin exchange no keys at all.
    for (i, r) in results.iter().enumerate() {
        if i != 2 {
            assert_no_key_traffic(r, i + 1);
        }
    }
    // Round 3: exactly the returning node's key material moved — node 4
    // re-registers (1) and re-fetches its 5 peers; the 5 survivors
    // re-fetch node 4's key.
    let r3 = &results[2].metrics;
    assert_eq!(r3.per_path.get(proto::REGISTER_KEY), Some(&1));
    assert_eq!(r3.per_path.get(proto::GET_KEY), Some(&(5 + 5)));
    assert_eq!(r3.rekey_messages, 1 + 5 + 5);
    assert_eq!(r3.messages, 4 * 6, "rekey must not leak into the 4n count");
}

#[test]
fn churn_die_rejoin_die() {
    // Node 3 dies in round 1, returns in round 2, dies again (mid-chain,
    // after pulling its aggregate) in round 3, and is absent in round 4.
    let n = 6;
    let churn = ChurnSchedule::none()
        .die(3, 1, FailPoint::NeverStart)
        .rejoin(3, 2)
        .die(3, 3, FailPoint::AfterGet);
    let results = run_churn(n, 4, &churn);
    assert_round_mean(&results, 1, n, &[3]);
    assert_round_mean(&results, 2, n, &[]);
    assert_round_mean(&results, 3, n, &[3]);
    assert_round_mean(&results, 4, n, &[3]);
    // Per-round failover counts: in-round deaths cost a repost; absence
    // (already re-formed chain) costs nothing.
    assert_eq!(results[0].metrics.progress_failovers, 1);
    assert_eq!(results[1].metrics.progress_failovers, 0);
    assert_eq!(results[2].metrics.progress_failovers, 1);
    assert_eq!(results[3].metrics.progress_failovers, 0);
    assert!(results[1].metrics.rekey_messages > 0, "rejoin round re-keys");
    assert_no_key_traffic(&results[0], 1);
    assert_no_key_traffic(&results[2], 3);
    assert_no_key_traffic(&results[3], 4);
}

#[test]
fn churn_preneg_rekey_touches_only_rejoiner_links() {
    // §5.8 pre-negotiated mode: a rejoin refreshes every symmetric key on
    // links touching the rejoiner — and nothing between survivors.
    let n = 5;
    let mut c = churn_cfg(n);
    c.mode = CipherMode::PreNegotiated;
    let session = SafeSession::new(c).unwrap();
    let per_round: Vec<Vec<Vec<f64>>> = (0..3).map(|_| inputs(n)).collect();
    let churn = ChurnSchedule::none().die(5, 1, FailPoint::NeverStart).rejoin(5, 3);
    let results = session.run_rounds(&per_round, &churn).unwrap();
    assert_round_mean(&results, 1, n, &[5]);
    assert_round_mean(&results, 2, n, &[5]);
    assert_round_mean(&results, 3, n, &[]);
    assert_no_key_traffic(&results[0], 1);
    assert_no_key_traffic(&results[1], 2);
    let r3 = &results[2].metrics;
    // RSA layer: 1 re-register + 4 fetches by node 5 + 4 peer re-fetches.
    assert_eq!(r3.per_path.get(proto::REGISTER_KEY), Some(&1));
    assert_eq!(r3.per_path.get(proto::GET_KEY), Some(&8));
    // Symmetric layer: node 5 posts once and pulls 4; each of the 4 peers
    // posts its fresh key for node 5 and pulls node 5's key for it.
    assert_eq!(r3.per_path.get(proto::POST_PRENEG_KEYS), Some(&5));
    assert_eq!(r3.per_path.get(proto::GET_PRENEG_KEY), Some(&8));
    assert_eq!(r3.rekey_messages, 9 + 13);
    assert_eq!(r3.messages, 4 * 5);
}

#[test]
fn merge_rebalance_small_group_after_churn() {
    // A 3-node group loses a node: 6 nodes / 2 groups; node 6 (group 2)
    // dies after posting in round 1, so round 2's re-formed group 2 would
    // hold only {4, 5} — below the §5.3 floor. With merging on (the
    // default) the planner folds the survivors into group 1 instead of
    // aborting.
    let n = 6;
    let mut c = churn_cfg(n);
    c.groups = 2;
    let session = SafeSession::new(c).unwrap();
    let per_round: Vec<Vec<Vec<f64>>> = (0..2).map(|_| inputs(n)).collect();
    let churn = ChurnSchedule::none().die(6, 1, FailPoint::AfterPost);
    let results = session.run_rounds(&per_round, &churn).unwrap();

    // Round 1: node 6 contributed before dying — full average, two
    // groups, no merge, no key traffic.
    assert_round_mean(&results, 1, n, &[]);
    assert_eq!(results[0].metrics.merged_groups, 0);
    assert_eq!(results[0].metrics.reassigned_nodes, 0);
    assert_no_key_traffic(&results[0], 1);

    // Round 2: survivors merged, round completes with the correct
    // average over the 5 live nodes.
    assert_round_mean(&results, 2, n, &[6]);
    let r2 = &results[1].metrics;
    assert_eq!(r2.merged_groups, 1, "group 2 dissolved into group 1");
    assert_eq!(r2.reassigned_nodes, 2, "only nodes 4 and 5 moved");
    // Only reassigned nodes re-key, and only their *new* links: nodes 4
    // and 5 each fetch {1,2,3}'s keys and {1,2,3} each fetch both movers'
    // keys — 2 × 3 × 2 = 12 fetches, no re-registration, nothing between
    // unmoved survivors.
    assert_eq!(r2.per_path.get(proto::GET_KEY), Some(&12));
    assert!(!r2.per_path.contains_key(proto::REGISTER_KEY));
    assert!(!r2.per_path.contains_key(proto::POST_PRENEG_KEYS));
    assert_eq!(r2.rekey_messages, 12);
    // The §5.2 accounting still holds: one merged 5-node chain, no
    // failures → 4n + 2·0; the reassignment re-key delta is reported
    // separately (footnote 3 discipline), not folded into messages.
    assert_eq!(r2.messages, 4 * 5);
    assert_eq!(r2.progress_failovers, 0);
}

#[test]
fn merge_then_rejoin_restores_home_groups() {
    // After a merge round, the dead node returns: the home 2-group
    // topology is restored and only the rejoiner's key material moves
    // (the movers already hold their cross-group keys — a repeated merge
    // or un-merge is key-traffic-free for them).
    let n = 6;
    let mut c = churn_cfg(n);
    c.groups = 2;
    let session = SafeSession::new(c).unwrap();
    let per_round: Vec<Vec<Vec<f64>>> = (0..3).map(|_| inputs(n)).collect();
    let churn = ChurnSchedule::none().die(6, 1, FailPoint::AfterPost).rejoin(6, 3);
    let results = session.run_rounds(&per_round, &churn).unwrap();
    assert_eq!(results[1].metrics.merged_groups, 1);
    let r3 = &results[2].metrics;
    assert_round_mean(&results, 3, n, &[]);
    assert_eq!(r3.merged_groups, 0, "home topology restored");
    assert_eq!(r3.reassigned_nodes, 0);
    // Two groups again → 4n + g messages; rejoiner-only re-key: node 6
    // re-registers (1), fetches its 2 group peers, and they re-fetch it.
    assert_eq!(r3.messages, 4 * 6 + 2);
    assert_eq!(r3.per_path.get(proto::REGISTER_KEY), Some(&1));
    assert_eq!(r3.per_path.get(proto::GET_KEY), Some(&4));
    assert_eq!(r3.rekey_messages, 1 + 2 + 2);
}

#[test]
fn merge_floor_off_aborts_under_floor_group() {
    // Same churn as the merge test, but --merge-floor off: round 2 must
    // refuse up front with a privacy-floor error instead of merging.
    let n = 6;
    let mut c = churn_cfg(n);
    c.groups = 2;
    c.merge_floor = false;
    let session = SafeSession::new(c).unwrap();
    let per_round: Vec<Vec<Vec<f64>>> = (0..2).map(|_| inputs(n)).collect();
    let churn = ChurnSchedule::none().die(6, 1, FailPoint::AfterPost);
    let err = session.run_rounds(&per_round, &churn).unwrap_err();
    assert!(
        format!("{err:#}").contains("privacy floor"),
        "round 2 must abort when merging is disabled: {err:#}"
    );
}

#[test]
fn churn_absence_window_respects_privacy_floor() {
    // Nodes 3 and 4 die *after posting* in round 1 (their values count,
    // the chain completes cleanly) — but the re-formed round-2 chain
    // would have only 2 live nodes, which §5.3's privacy floor forbids.
    // The engine must refuse the round up front, not hang in it.
    let churn = ChurnSchedule::none()
        .die(3, 1, FailPoint::AfterPost)
        .die(4, 1, FailPoint::AfterPost);
    let session = SafeSession::new(cfg(4)).unwrap();
    let per_round: Vec<Vec<Vec<f64>>> = (0..2).map(|_| inputs(4)).collect();
    let err = session.run_rounds(&per_round, &churn).unwrap_err();
    assert!(
        format!("{err:#}").contains("privacy floor"),
        "round 2 with 2 live nodes must abort: {err:#}"
    );
}

// ---- sharded aggregation plane (K > 1) under churn ----

#[test]
fn sharded_cross_shard_merge() {
    // 20 nodes / 4 groups over K=2 shards (round-robin: g1,g3 → shard 0;
    // g2,g4 → shard 1). Group 2 loses 7/8/9 after posting in round 1, so
    // its round-2 projection {6,10} is under the §5.3 floor; the planner
    // folds the survivors into the earlier same-size neighbour g1 — a
    // *cross-shard* move (shard 1 → shard 0) that must re-key exactly the
    // new links and leave the fan-in accounting untouched.
    let n = 20;
    let mut c = churn_cfg(n);
    c.groups = 4;
    c.shards = 2;
    let session = SafeSession::new(c).unwrap();
    let per_round: Vec<Vec<Vec<f64>>> = (0..2).map(|_| inputs(n)).collect();
    let churn = ChurnSchedule::none()
        .die(7, 1, FailPoint::AfterPost)
        .die(8, 1, FailPoint::AfterPost)
        .die(9, 1, FailPoint::AfterPost);
    let results = session.run_rounds(&per_round, &churn).unwrap();

    // Round 1: every node contributed before dying, and with equal group
    // sizes the contributor-weighted shard combine equals the plain mean.
    assert_round_mean(&results, 1, n, &[]);
    let r1 = &results[0].metrics;
    assert_eq!(r1.merged_groups, 0);
    assert_eq!(r1.reassigned_nodes, 0);
    assert_eq!(r1.fanin_messages, 4, "2 live shards × (partial post + global fetch)");
    assert_no_key_traffic(&results[0], 1);

    // Round 2: the merge crossed a shard boundary.
    let r2 = &results[1].metrics;
    assert_eq!(r2.merged_groups, 1, "group 2 dissolved into group 1");
    assert_eq!(r2.reassigned_nodes, 2, "only nodes 6 and 10 moved");
    assert_eq!(r2.contributors, 17);
    // Movers fetch their 5 new peers' keys and vice versa — key material
    // crosses shards through the key plane (the fan-in parent), with no
    // re-registration and nothing between unmoved survivors.
    assert_eq!(r2.per_path.get(proto::GET_KEY), Some(&20));
    assert!(!r2.per_path.contains_key(proto::REGISTER_KEY));
    assert_eq!(r2.rekey_messages, 20);
    // §5.2 accounting across shards: 17 contributors in 3 chains → 4n + g,
    // with the fan-in surcharge still 2 per live shard (g4 kept shard 1
    // alive) and counted separately.
    assert_eq!(r2.messages, 4 * 17 + 3);
    assert_eq!(r2.fanin_messages, 4);
    assert_eq!(r2.shard_messages.len(), 2);
    assert_eq!(r2.shard_messages.iter().sum::<u64>(), r2.messages);
    // The sharded global is the contributor-weighted combine of shard
    // partials (each an equal-weight mean of its group means) — with
    // unequal post-merge group sizes this is NOT the plain mean, so the
    // expectation is computed explicitly: shard 0 = (mean{1..6,10} +
    // mean{11..15})/2 over 12 contributors, shard 1 = mean{16..20} over 5.
    let m1 = (1 + 2 + 3 + 4 + 5 + 6 + 10) as f64 / 7.0;
    let (m3, m4) = (13.0, 18.0);
    let want = (((m1 + m3) / 2.0) * 12.0 + m4 * 5.0) / 17.0;
    let got = results[1].average().unwrap();
    assert!((got[0] - want).abs() < 1e-6, "got {} want {want}", got[0]);
    assert!((got[1] - 10.0 * want).abs() < 1e-5, "feature 1 is 10× feature 0");
}

#[test]
fn shard_death_degrades_to_partial_global() {
    // Component-level shard death: a fan-in parent expecting 2 children
    // hears from only one. The live shard's worker sequence must time out
    // on the completion fetch, degrade to the partial combine, and
    // *install* it — at which point the shard's parked `get_average`
    // pollers (held back by fan-in mode despite the local §5.5 barrier
    // being complete) release with the degraded global. The session
    // engine can't reach this state through scheduled churn (the planner
    // proactively merges a whole-group death away), so it's pinned here.
    use std::sync::Arc;

    use safe_agg::controller::{Controller, ControllerConfig};
    use safe_agg::protocols::hierarchy::FederationBridge;
    use safe_agg::transport::{ClientTransport, Handler, InProcTransport};

    let ctrl_cfg = || ControllerConfig {
        poll_time: Duration::from_millis(100),
        ..Default::default()
    };
    let parent = Arc::new(Controller::new(ctrl_cfg()));
    let parent_br = proto::BeginRound {
        epoch: 1,
        groups: Default::default(),
        merge_floor: false,
        reassigned: vec![],
        fanin: false,
        fed_children: Some(2),
    };
    assert_eq!(
        parent.handle(proto::BEGIN_ROUND, &parent_br.to_value()).str_of("status"),
        Some("ok")
    );

    let shard = Arc::new(Controller::new(ctrl_cfg()));
    let shard_br = proto::BeginRound {
        epoch: 1,
        groups: std::collections::BTreeMap::from([(1u64, vec![1u64, 2, 3])]),
        merge_floor: false,
        reassigned: vec![],
        fanin: true,
        fed_children: None,
    };
    assert_eq!(
        shard.handle(proto::BEGIN_ROUND, &shard_br.to_value()).str_of("status"),
        Some("ok")
    );
    assert_eq!(
        shard
            .handle(proto::POST_AVERAGE, &proto::post_average(1, 1, &[6.0, 60.0], 3))
            .str_of("status"),
        Some("ok")
    );

    // The local barrier is complete, but in fan-in mode learners must NOT
    // be released with the shard-local mean — only the installed global.
    assert!(
        proto::is_empty_status(&shard.handle(proto::GET_AVERAGE, &proto::node_op(2, 1))),
        "fan-in shard released a poller before the global was installed"
    );

    // The fan-in worker's path: barrier wait → partial → post upward.
    let (partial, contributors) = shard.shard_partial(Duration::from_millis(300)).unwrap();
    assert_eq!(contributors, 3);
    assert_eq!(partial, vec![6.0, 60.0]);
    let transport: Arc<dyn ClientTransport> = Arc::new(InProcTransport::new(parent.clone()));
    let bridge = FederationBridge::new(1, transport);
    bridge.post_child_average(&partial, contributors).unwrap();

    // Child 2 never posts: the global fetch times out and the degraded
    // partial — just this shard's contribution — is served instead.
    assert!(bridge.try_get_global_average(Duration::from_millis(250)).unwrap().is_none());
    let (global, weight) = bridge.get_partial_global().unwrap().unwrap();
    assert_eq!(weight, 3);
    assert_eq!(global, vec![6.0, 60.0]);

    // Installing releases the parked pollers with the degraded global.
    shard.install_global_average(global, weight);
    let resp = shard.handle(proto::GET_AVERAGE, &proto::node_op(2, 1));
    assert_eq!(resp.str_of("status"), Some("ok"));
    assert_eq!(resp.f64_arr_of("average").unwrap(), vec![6.0, 60.0]);
    assert_eq!(resp.u64_of("groups"), Some(3), "weight rides in the groups field");
}

#[test]
fn subgroup_failure_isolated_to_one_group() {
    // §5.5: "a single node failure does not break the entire aggregation,
    // just a single subgroup". 8 nodes in 2 groups; node 6 (group 2) dies.
    let mut c = cfg(8);
    c.groups = 2;
    let session = SafeSession::new(c).unwrap();
    let faults = FaultPlan::none().kill(6, FailPoint::NeverStart);
    let result = session.run_round(&inputs(8), &faults).unwrap();
    // Group 1 average: (1+2+3+4)/4 = 2.5; group 2: (5+7+8)/3 = 6.667;
    // global = mean of group means.
    let expect = (2.5 + (5.0 + 7.0 + 8.0) / 3.0) / 2.0;
    assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
}
