//! Failure-injection matrix: every fault point × position combination the
//! protocol must survive (§5.3/§5.4), plus multi-failure and adjacent-
//! failure cases the paper calls out as the hard ones.

use std::time::Duration;

use safe_agg::config::{DeviceProfile, SessionConfig};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::learner::faults::{FailPoint, FaultPlan};
use safe_agg::protocols::SafeSession;

fn cfg(n: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        features: 2,
        mode: CipherMode::Hybrid,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        poll_time: Duration::from_millis(120),
        aggregation_timeout: Duration::from_secs(2),
        progress_timeout: Duration::from_millis(400),
        monitor_interval: Duration::from_millis(60),
        ..Default::default()
    }
}

fn inputs(n: usize) -> Vec<Vec<f64>> {
    (1..=n).map(|i| vec![i as f64, 10.0 * i as f64]).collect()
}

fn expect_mean(n: usize, dead: &[u64]) -> f64 {
    let alive: Vec<f64> = (1..=n as u64)
        .filter(|i| !dead.contains(i))
        .map(|i| i as f64)
        .collect();
    alive.iter().sum::<f64>() / alive.len() as f64
}

fn run_case(n: usize, faults: FaultPlan, dead_contributors: &[u64]) {
    let session = SafeSession::new(cfg(n)).unwrap();
    let result = session.run_round(&inputs(n), &faults).unwrap();
    let expect = expect_mean(n, dead_contributors);
    assert!(
        (result.average().unwrap()[0] - expect).abs() < 1e-6,
        "n={n} faults={faults:?}: got {} want {expect}",
        result.average().unwrap()[0]
    );
    assert_eq!(
        result.metrics.contributors,
        (n - dead_contributors.len()) as u64,
        "contributor count for {faults:?}"
    );
}

#[test]
fn single_failure_every_noninitiator_position() {
    // A node at each non-initiator position dies before starting.
    for pos in 2..=6u64 {
        run_case(6, FaultPlan::none().kill(pos, FailPoint::NeverStart), &[pos]);
    }
}

#[test]
fn failure_after_get_is_recovered() {
    // The hard case from §5.3: the mailbox was already drained when the
    // node died, so the monitor must reconstruct the stuck link from the
    // poster set.
    for pos in 2..=5u64 {
        run_case(6, FaultPlan::none().kill(pos, FailPoint::AfterGet), &[pos]);
    }
}

#[test]
fn failure_after_post_keeps_contribution() {
    // Dying after posting: the value IS in the aggregate; only the dead
    // node misses the result. Average must cover all n nodes.
    let n = 5;
    let session = SafeSession::new(cfg(n)).unwrap();
    let faults = FaultPlan::none().kill(3, FailPoint::AfterPost);
    let result = session.run_round(&inputs(n), &faults).unwrap();
    let expect = (1..=5).sum::<i32>() as f64 / 5.0;
    assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
    assert_eq!(result.metrics.contributors, 5);
    // The dead node has no average; survivors do.
    assert_eq!(result.survivors().len(), 4);
}

#[test]
fn two_adjacent_failures() {
    // §5.3 explicitly worries about "two nodes next to each other on the
    // chain fail simultaneously".
    run_case(
        7,
        FaultPlan::none()
            .kill(3, FailPoint::NeverStart)
            .kill(4, FailPoint::NeverStart),
        &[3, 4],
    );
}

#[test]
fn three_failures_spread_out() {
    run_case(
        9,
        FaultPlan::none()
            .kill(2, FailPoint::NeverStart)
            .kill(5, FailPoint::AfterGet)
            .kill(8, FailPoint::NeverStart),
        &[2, 5, 8],
    );
}

#[test]
fn last_node_failure() {
    // The failed node is the one that would close the loop back to the
    // initiator — repost must wrap around the chain end.
    run_case(5, FaultPlan::none().kill(5, FailPoint::NeverStart), &[5]);
}

#[test]
fn initiator_crash_recovers_with_new_initiator() {
    let n = 5;
    let session = SafeSession::new(cfg(n)).unwrap();
    let faults = FaultPlan::none().kill(1, FailPoint::InitiatorAfterPost);
    let result = session.run_round(&inputs(n), &faults).unwrap();
    assert!(result.metrics.initiator_failovers >= 1);
    let expect = (2 + 3 + 4 + 5) as f64 / 4.0;
    assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
    let new_init = result
        .outcomes
        .iter()
        .find(|o| !o.died && o.was_initiator)
        .unwrap()
        .node;
    assert_ne!(new_init, 1);
}

#[test]
fn initiator_crash_plus_noninitiator_failure() {
    // Compound: the initiator dies AND node 4 never starts.
    let n = 6;
    let session = SafeSession::new(cfg(n)).unwrap();
    let faults = FaultPlan::none()
        .kill(1, FailPoint::InitiatorAfterPost)
        .kill(4, FailPoint::NeverStart);
    let result = session.run_round(&inputs(n), &faults).unwrap();
    let expect = (2 + 3 + 5 + 6) as f64 / 4.0;
    assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
    assert_eq!(result.metrics.contributors, 4);
}

#[test]
fn subgroup_failure_isolated_to_one_group() {
    // §5.5: "a single node failure does not break the entire aggregation,
    // just a single subgroup". 8 nodes in 2 groups; node 6 (group 2) dies.
    let mut c = cfg(8);
    c.groups = 2;
    let session = SafeSession::new(c).unwrap();
    let faults = FaultPlan::none().kill(6, FailPoint::NeverStart);
    let result = session.run_round(&inputs(8), &faults).unwrap();
    // Group 1 average: (1+2+3+4)/4 = 2.5; group 2: (5+7+8)/3 = 6.667;
    // global = mean of group means.
    let expect = (2.5 + (5.0 + 7.0 + 8.0) / 3.0) / 2.0;
    assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
}
