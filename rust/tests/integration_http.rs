//! Integration: the full SAFE protocol over the real HTTP transport —
//! controller served on a loopback socket, learners as HTTP clients,
//! exactly the paper's REST deployment shape.

use std::time::Duration;

use safe_agg::config::{DeviceProfile, SessionConfig, TransportKind};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::learner::faults::{FailPoint, FaultPlan};
use safe_agg::protocols::SafeSession;

fn http_cfg(n: usize, features: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        features,
        mode: CipherMode::Hybrid,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        transport: TransportKind::Http { url: "spawn".into() },
        poll_time: Duration::from_millis(150),
        aggregation_timeout: Duration::from_secs(15),
        progress_timeout: Duration::from_millis(800),
        monitor_interval: Duration::from_millis(100),
        ..Default::default()
    }
}

fn inputs(n: usize, features: usize) -> Vec<Vec<f64>> {
    (1..=n)
        .map(|i| (0..features).map(|f| i as f64 * 2.0 + f as f64 * 0.25).collect())
        .collect()
}

#[test]
fn safe_round_over_http() {
    let cfg = http_cfg(4, 3);
    let session = SafeSession::new(cfg).unwrap();
    let ins = inputs(4, 3);
    let result = session.run_round(&ins, &FaultPlan::none()).unwrap();
    // mean of 2,4,6,8 = 5 for feature 0
    assert!((result.average().unwrap()[0] - 5.0).abs() < 1e-6);
    assert_eq!(result.metrics.contributors, 4);
}

#[test]
fn safe_http_with_progress_failover() {
    let cfg = http_cfg(6, 2);
    let session = SafeSession::new(cfg).unwrap();
    let ins = inputs(6, 2);
    let result = session
        .run_round(&ins, &FaultPlan::none().kill(3, FailPoint::AfterGet))
        .unwrap();
    // Node 3 consumed then died: 5 contributors.
    assert_eq!(result.metrics.contributors, 5);
    assert!(result.metrics.progress_failovers >= 1);
    let expect = (2.0 + 4.0 + 8.0 + 10.0 + 12.0) / 5.0;
    assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
}

#[test]
fn safe_http_large_vectors() {
    let cfg = http_cfg(3, 5000);
    let session = SafeSession::new(cfg).unwrap();
    let ins = inputs(3, 5000);
    let result = session.run_round(&ins, &FaultPlan::none()).unwrap();
    assert_eq!(result.average().unwrap().len(), 5000);
    // spot-check a few features
    for f in [0usize, 1234, 4999] {
        let expect = (ins[0][f] + ins[1][f] + ins[2][f]) / 3.0;
        assert!((result.average().unwrap()[f] - expect).abs() < 1e-6, "feature {f}");
    }
}

#[test]
fn repeated_rounds_reuse_session() {
    // Key exchange happens once; aggregation rounds repeat (paper
    // footnote 3). Runs 3 rounds on one session over HTTP.
    let cfg = http_cfg(4, 2);
    let session = SafeSession::new(cfg).unwrap();
    for round in 0..3 {
        let ins: Vec<Vec<f64>> =
            (1..=4).map(|i| vec![(i * (round + 1)) as f64; 2]).collect();
        let result = session.run_round(&ins, &FaultPlan::none()).unwrap();
        let expect = (1 + 2 + 3 + 4) as f64 * (round + 1) as f64 / 4.0;
        assert!((result.average().unwrap()[0] - expect).abs() < 1e-6, "round {round}");
    }
}
