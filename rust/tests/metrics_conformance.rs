//! Metrics-conformance suite: scrape `GET /metrics` from live sessions
//! and hold the exposition text to the schema.
//!
//! Every check runs against text served through the real endpoint
//! ([`safe_agg::proto::METRICS`] over an in-proc client against each
//! plane controller), not against registry internals:
//!
//! - every sample belongs to a family with a `# TYPE` line, every
//!   family is in the schema ([`safe_agg::metrics::names`]) with the
//!   `safe_` prefix, and label keys stay inside the documented set
//!   (`path`, `shard`, `class`, `le`);
//! - counters (and histogram `_bucket`/`_count` series) are monotone
//!   across successive scrapes of a running session;
//! - histograms are internally consistent per scrape: buckets cumulative
//!   in `le` order, the `+Inf` bucket equal to `_count`, `_sum`
//!   non-negative for duration metrics;
//! - the §5.3 monitor's scrape-visible traffic never perturbs the
//!   `4n + 2f (+g)` message accounting — the class-level filtering
//!   regression test for the old `PROGRESS_CHECK` special-case.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use safe_agg::config::{DeviceProfile, RuntimeKind, SessionConfig};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::learner::faults::ChurnSchedule;
use safe_agg::metrics::names;
use safe_agg::proto;
use safe_agg::protocols::SafeSession;
use safe_agg::transport::{ClientTransport, InProcTransport};

/// One parsed sample line: `name{labels} value`.
#[derive(Debug, Clone)]
struct Sample {
    name: String,
    labels: BTreeMap<String, String>,
    value: f64,
}

/// A parsed scrape: family types plus every sample, in order.
#[derive(Debug, Clone)]
struct Scrape {
    types: BTreeMap<String, String>,
    samples: Vec<Sample>,
}

/// Parse Prometheus text exposition format (the subset the registry
/// emits: no timestamps, no exemplars). Panics on malformed lines so a
/// formatting regression fails loudly.
fn parse_exposition(text: &str) -> Scrape {
    let mut types = BTreeMap::new();
    let mut samples = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.splitn(2, ' ');
            let name = it.next().expect("TYPE line has a family name");
            let kind = it.next().expect("TYPE line has a kind");
            types.insert(name.to_string(), kind.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or snapshot section marker
        }
        let (series, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("no value in sample line: {line}"));
        let (name, labels) = match series.split_once('{') {
            Some((name, rest)) => {
                let inner = rest
                    .strip_suffix('}')
                    .unwrap_or_else(|| panic!("unterminated label set: {line}"));
                let mut labels = BTreeMap::new();
                for pair in split_label_pairs(inner) {
                    let (k, v) = pair
                        .split_once('=')
                        .unwrap_or_else(|| panic!("label pair without '=': {line}"));
                    let v = v
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .unwrap_or_else(|| panic!("unquoted label value: {line}"));
                    labels.insert(k.to_string(), v.replace("\\\"", "\"").replace("\\\\", "\\"));
                }
                (name.to_string(), labels)
            }
            None => (series.to_string(), BTreeMap::new()),
        };
        let value = match value {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            v => v.parse().unwrap_or_else(|_| panic!("unparsable value in: {line}")),
        };
        samples.push(Sample { name, labels, value });
    }
    Scrape { types, samples }
}

/// Split `k1="v1",k2="v2"` on commas outside quotes (label values may
/// contain commas in principle, even though the session's never do).
fn split_label_pairs(inner: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut escaped = false;
    for c in inner.chars() {
        match c {
            '\\' if in_quotes && !escaped => {
                escaped = true;
                cur.push(c);
            }
            '"' if !escaped => {
                in_quotes = !in_quotes;
                cur.push(c);
            }
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            _ => {
                escaped = false;
                cur.push(c);
            }
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Family a sample belongs to: histogram sample suffixes stripped when
/// the bare name has no TYPE of its own.
fn family_of(types: &BTreeMap<String, String>, sample: &str) -> String {
    if types.contains_key(sample) {
        return sample.to_string();
    }
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = sample.strip_suffix(suffix) {
            if types.get(base).map(String::as_str) == Some("histogram") {
                return base.to_string();
            }
        }
    }
    panic!("sample {sample} has no # TYPE family");
}

/// The complete metric schema: every family the session may emit.
fn schema() -> BTreeSet<&'static str> {
    [
        names::REQUESTS_TOTAL,
        names::REQUEST_BYTES_TOTAL,
        names::RESPONSE_BYTES_TOTAL,
        names::NET_RETRIES_TOTAL,
        names::NET_DROPS_TOTAL,
        names::DEDUP_POSTS_TOTAL,
        names::ROUNDS_TOTAL,
        names::PROGRESS_FAILOVERS_TOTAL,
        names::INITIATOR_FAILOVERS_TOTAL,
        names::REKEY_MESSAGES_TOTAL,
        names::MERGED_GROUPS_TOTAL,
        names::REASSIGNED_NODES_TOTAL,
        names::DEADLINE_EXCEEDED_TOTAL,
        names::FANIN_MESSAGES_TOTAL,
        names::MONITOR_REPOSTS_TOTAL,
        names::MONITOR_ABORTS_TOTAL,
        names::MONITOR_MERGE_SIGNALS_TOTAL,
        names::LIVE_NODES,
        names::CURRENT_ROUND,
        names::CONTROLLER_WAITING_POLLS,
        names::CONTROLLER_PEAK_WAITING_POLLS,
        names::CONTROLLER_INFO,
        names::REQUEST_DURATION_SECONDS,
        names::ROUND_DURATION_SECONDS,
        names::FANIN_DURATION_SECONDS,
    ]
    .into_iter()
    .collect()
}

/// Scrape one controller through the real endpoint and return the text.
fn scrape(transport: &InProcTransport) -> String {
    let resp = transport
        .call(proto::METRICS, &safe_agg::json::Value::obj())
        .expect("metrics endpoint answers");
    resp.str_of("text").expect("metrics response carries text").to_string()
}

/// Schema conformance of one scrape.
fn check_schema(s: &Scrape) {
    let allowed_labels: BTreeSet<&str> = ["path", "shard", "class", "le"].into_iter().collect();
    let known = schema();
    for (family, kind) in &s.types {
        assert!(
            family.starts_with("safe_"),
            "family {family} missing the safe_ prefix"
        );
        assert!(known.contains(family.as_str()), "family {family} not in the schema");
        assert!(
            matches!(kind.as_str(), "counter" | "gauge" | "histogram"),
            "family {family} has unknown kind {kind}"
        );
    }
    for sample in &s.samples {
        let fam = family_of(&s.types, &sample.name);
        assert!(known.contains(fam.as_str()), "sample {} outside the schema", sample.name);
        for key in sample.labels.keys() {
            assert!(
                allowed_labels.contains(key.as_str()),
                "sample {} carries undocumented label {key}",
                sample.name
            );
        }
        if let Some(class) = sample.labels.get("class") {
            let path = sample.labels.get("path").expect("class label implies path label");
            assert_eq!(
                class,
                safe_agg::metrics::path_class(path),
                "sample {}: class label disagrees with path_class({path})",
                sample.name
            );
        }
    }
}

/// Histogram internal invariants of one scrape.
fn check_histograms(s: &Scrape) {
    // Group bucket samples by (family, labels-minus-le).
    type SeriesKey = (String, BTreeMap<String, String>);
    let mut buckets: BTreeMap<SeriesKey, Vec<(f64, f64)>> = BTreeMap::new();
    let mut sums: BTreeMap<SeriesKey, f64> = BTreeMap::new();
    let mut counts: BTreeMap<SeriesKey, f64> = BTreeMap::new();
    for sample in &s.samples {
        if let Some(base) = sample.name.strip_suffix("_bucket") {
            if s.types.get(base).map(String::as_str) != Some("histogram") {
                continue;
            }
            let mut labels = sample.labels.clone();
            let le = labels.remove("le").expect("bucket sample has le");
            let le = if le == "+Inf" { f64::INFINITY } else { le.parse().unwrap() };
            buckets.entry((base.to_string(), labels)).or_default().push((le, sample.value));
        } else if let Some(base) = sample.name.strip_suffix("_sum") {
            if s.types.get(base).map(String::as_str) == Some("histogram") {
                sums.insert((base.to_string(), sample.labels.clone()), sample.value);
            }
        } else if let Some(base) = sample.name.strip_suffix("_count") {
            if s.types.get(base).map(String::as_str) == Some("histogram") {
                counts.insert((base.to_string(), sample.labels.clone()), sample.value);
            }
        }
    }
    assert!(!buckets.is_empty(), "scrape rendered no histogram series");
    for (key, series) in &mut buckets {
        series.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let last = series.last().expect("non-empty bucket series");
        assert!(
            last.0.is_infinite(),
            "{}: histogram is missing its +Inf bucket",
            key.0
        );
        for pair in series.windows(2) {
            assert!(
                pair[0].1 <= pair[1].1,
                "{}: buckets not cumulative (le {} has {} > le {}'s {})",
                key.0,
                pair[0].0,
                pair[0].1,
                pair[1].0,
                pair[1].1
            );
        }
        let count = counts.get(key).unwrap_or_else(|| panic!("{}: missing _count", key.0));
        assert_eq!(last.1, *count, "{}: +Inf bucket != _count", key.0);
        let sum = sums.get(key).unwrap_or_else(|| panic!("{}: missing _sum", key.0));
        assert!(*sum >= 0.0, "{}: negative duration sum {sum}", key.0);
    }
}

/// Monotonicity of counters (and histogram buckets/counts) between two
/// successive scrapes of the same session.
fn check_monotone(earlier: &Scrape, later: &Scrape) {
    let later_by_series: BTreeMap<(String, BTreeMap<String, String>), f64> = later
        .samples
        .iter()
        .map(|s| ((s.name.clone(), s.labels.clone()), s.value))
        .collect();
    for sample in &earlier.samples {
        let fam = family_of(&earlier.types, &sample.name);
        let monotone = earlier.types.get(&fam).map(String::as_str) == Some("counter")
            || sample.name.ends_with("_bucket")
            || sample.name.ends_with("_count");
        if !monotone {
            continue;
        }
        if let Some(&lv) = later_by_series.get(&(sample.name.clone(), sample.labels.clone())) {
            assert!(
                lv >= sample.value,
                "{}{:?} went backwards: {} -> {}",
                sample.name,
                sample.labels,
                sample.value,
                lv
            );
        }
    }
}

fn cfg(n: usize, groups: usize, runtime: RuntimeKind, shards: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        groups,
        features: 2,
        mode: CipherMode::None,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        poll_time: Duration::from_secs(10),
        aggregation_timeout: Duration::from_secs(60),
        progress_timeout: Duration::from_secs(2),
        monitor_interval: Duration::from_millis(20),
        merge_floor: true,
        seed: Some(5),
        runtime,
        shards,
        ..Default::default()
    }
}

fn inputs(n: usize, rounds: usize) -> Vec<Vec<Vec<f64>>> {
    (0..rounds)
        .map(|r| (1..=n).map(|i| vec![(i * (r + 1)) as f64, 0.5 * i as f64]).collect())
        .collect()
}

/// Run a session while a side thread scrapes shard 0 continuously;
/// return every snapshot in order (initial, live…, one per controller at
/// the end) plus the per-round messages the engine reported.
fn run_and_scrape(cfg: SessionConfig, rounds: usize) -> (Vec<String>, Vec<u64>) {
    let n = cfg.n_nodes;
    let session = SafeSession::new(cfg).expect("session builds");
    let snapshots = Arc::new(Mutex::new(Vec::new()));
    let first = session.plane_controllers().remove(0);
    let live = InProcTransport::new(first.1.clone());
    snapshots.lock().unwrap().push(scrape(&live));

    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let stop = stop.clone();
        let snapshots = snapshots.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                snapshots.lock().unwrap().push(scrape(&live));
                std::thread::sleep(Duration::from_millis(15));
            }
        })
    };
    let results = session.run_rounds(&inputs(n, rounds), &ChurnSchedule::none());
    stop.store(true, Ordering::SeqCst);
    scraper.join().unwrap();
    let results = results.expect("rounds complete");

    let mut all = std::mem::take(&mut *snapshots.lock().unwrap());
    // Final quiescent scrape of every plane controller, parent included.
    for (_, ctrl) in session.plane_controllers() {
        all.push(scrape(&InProcTransport::new(ctrl)));
    }
    (all, results.iter().map(|r| r.metrics.messages).collect())
}

fn conformance(runtime: RuntimeKind, shards: usize) {
    let n = 10;
    let rounds = 2;
    let (snapshots, _) = run_and_scrape(cfg(n, 2, runtime, shards), rounds);
    assert!(snapshots.len() >= 3, "expected initial + live + final scrapes");
    let parsed: Vec<Scrape> = snapshots.iter().map(|s| parse_exposition(s)).collect();
    for s in &parsed {
        check_schema(s);
    }
    // The final scrapes have seen whole rounds — full histogram checks
    // there (early scrapes may predate any latency observation).
    let last = parsed.last().unwrap();
    check_histograms(last);
    for pair in parsed.windows(2) {
        check_monotone(&pair[0], &pair[1]);
    }
    // The round counters must reflect the finished run.
    let rounds_sample = last
        .samples
        .iter()
        .find(|s| s.name == names::ROUNDS_TOTAL)
        .expect("rounds counter scraped");
    assert_eq!(rounds_sample.value, rounds as f64);
    // Request counters carry the documented labels and cover the chain.
    assert!(
        last.samples.iter().any(|s| {
            s.name == names::REQUESTS_TOTAL
                && s.labels.get("class").map(String::as_str) == Some("chain")
        }),
        "no chain-class request series scraped"
    );
    // Latency histograms exist for the learner paths on every runtime.
    assert!(
        last.samples.iter().any(|s| {
            s.name == format!("{}_count", names::REQUEST_DURATION_SECONDS)
                && s.labels.get("class").map(String::as_str) == Some("chain")
                && s.value > 0.0
        }),
        "no chain-path latency observed"
    );
}

#[test]
fn scrape_conforms_under_event_runtime() {
    conformance(RuntimeKind::Events, 1);
}

#[test]
fn scrape_conforms_under_thread_runtime() {
    conformance(RuntimeKind::Threads, 1);
}

#[test]
fn scrape_conforms_on_sharded_plane() {
    let n = 10;
    let (snapshots, _) = run_and_scrape(cfg(n, 2, RuntimeKind::Events, 2), 2);
    let parsed: Vec<Scrape> = snapshots.iter().map(|s| parse_exposition(s)).collect();
    for s in &parsed {
        check_schema(s);
    }
    check_histograms(parsed.last().unwrap());
    for pair in parsed.windows(2) {
        check_monotone(&pair[0], &pair[1]);
    }
    // The sharded plane labels series per source: both shards and the
    // fan-in parent must appear on the final scrape.
    let last = parsed.last().unwrap();
    let shard_labels: BTreeSet<&str> = last
        .samples
        .iter()
        .filter(|s| s.name == names::REQUESTS_TOTAL)
        .filter_map(|s| s.labels.get("shard").map(String::as_str))
        .collect();
    for want in ["0", "1", "parent"] {
        assert!(shard_labels.contains(want), "no request series labeled shard={want}");
    }
    // And the fan-in tier recorded traffic plus its latency histogram.
    assert!(
        last.samples.iter().any(|s| s.name == names::FANIN_MESSAGES_TOTAL && s.value > 0.0),
        "fan-in counter empty on a K=2 plane"
    );
}

/// Regression for the monitor special-case: the §5.3 monitor hammers
/// `progress_check` throughout the round, its traffic is scrape-visible
/// as monitor-class series, and the engine's class-level filtering keeps
/// it out of the `4n + 2f (+g)` accounting exactly.
#[test]
fn monitor_traffic_never_perturbs_the_formula() {
    let n = 8;
    let g = 2u64;
    let mut c = cfg(n, g as usize, RuntimeKind::Events, 1);
    // An aggressive monitor: ~1 ping per ms, generous progress window so
    // none of the pings escalates into a repost.
    c.monitor_interval = Duration::from_millis(1);
    c.progress_timeout = Duration::from_secs(10);
    let session = SafeSession::new(c).expect("session builds");
    let results = session
        .run_rounds(&inputs(n, 2), &ChurnSchedule::none())
        .expect("rounds complete");

    for (i, r) in results.iter().enumerate() {
        assert_eq!(
            r.metrics.messages,
            4 * n as u64 + g,
            "round {}: monitor traffic leaked into the formula count",
            i + 1
        );
        assert_eq!(r.metrics.progress_failovers, 0);
        assert!(
            r.metrics.per_path.keys().all(|p| safe_agg::metrics::path_class(p) != "monitor"),
            "round {}: monitor-class path leaked into per_path: {:?}",
            i + 1,
            r.metrics.per_path
        );
    }

    // The filtering was non-vacuous: the registry saw plenty of monitor
    // traffic even though the round accounting saw none.
    let ctrl = session.plane_controllers().remove(0).1;
    let text = scrape(&InProcTransport::new(ctrl));
    let s = parse_exposition(&text);
    let monitor_requests: f64 = s
        .samples
        .iter()
        .filter(|smp| {
            smp.name == names::REQUESTS_TOTAL
                && smp.labels.get("class").map(String::as_str) == Some("monitor")
        })
        .map(|smp| smp.value)
        .sum();
    assert!(
        monitor_requests > 0.0,
        "monitor never pinged — the regression test lost its subject"
    );
}
