//! Property-based tests on protocol invariants, using the in-house
//! testkit (proptest is not in the offline crate cache).
//!
//! Invariants covered:
//!  * masking cancels: SAFE's average equals the cleartext mean for any
//!    inputs, any node count, any cipher mode;
//!  * SAFE, INSEC and BON all converge to the same mean on the same data;
//!  * weighted encode/decode inverts for arbitrary weights;
//!  * Shamir share → reconstruct is the identity at ≥ t shares;
//!  * envelope seal/open roundtrips for every mode under arbitrary data;
//!  * chain routing: next_alive skips any failed set and stays in chain.

use std::time::Duration;

use safe_agg::config::{DeviceProfile, SessionConfig};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::crypto::rng::{DeterministicRng, SecureRng};
use safe_agg::learner::faults::FaultPlan;
use safe_agg::protocols::bon::BonSession;
use safe_agg::protocols::insec::InsecSession;
use safe_agg::protocols::{weighted, SafeSession};
use safe_agg::testkit;

fn quick_cfg(n: usize, features: usize, seed: u64) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        features,
        mode: CipherMode::Hybrid,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        poll_time: Duration::from_millis(150),
        aggregation_timeout: Duration::from_secs(15),
        progress_timeout: Duration::from_secs(4),
        seed: Some(seed),
        ..Default::default()
    }
}

fn mean(inputs: &[Vec<f64>]) -> Vec<f64> {
    let n = inputs.len() as f64;
    let mut out = vec![0.0; inputs[0].len()];
    for v in inputs {
        for (a, x) in out.iter_mut().zip(v) {
            *a += x;
        }
    }
    out.iter_mut().for_each(|a| *a /= n);
    out
}

fn random_inputs(rng: &mut DeterministicRng, n: usize, features: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| (0..features).map(|_| (rng.next_f64() - 0.5) * 200.0).collect())
        .collect()
}

#[test]
fn prop_safe_average_equals_cleartext_mean() {
    testkit::check(
        "safe-mean",
        6,
        |rng| {
            let n = 3 + rng.next_below(4); // 3..6 nodes
            let features = 1 + rng.next_below(16);
            let inputs = random_inputs(rng, n, features);
            (n, features, inputs, rng.next_u64())
        },
        |(n, features, inputs, seed)| {
            let session = SafeSession::new(quick_cfg(*n, *features, *seed)).unwrap();
            let result = session.run_round(inputs, &FaultPlan::none()).unwrap();
            let expect = mean(inputs);
            let avg = result.average().unwrap();
            avg.iter().zip(&expect).all(|(a, e)| (a - e).abs() < 1e-6)
        },
    );
}

#[test]
fn prop_all_protocols_agree() {
    testkit::check(
        "protocols-agree",
        3,
        |rng| {
            let n = 3 + rng.next_below(3);
            let inputs = random_inputs(rng, n, 4);
            (n, inputs, rng.next_u64())
        },
        |(n, inputs, seed)| {
            let expect = mean(inputs);
            let safe = SafeSession::new(quick_cfg(*n, 4, *seed))
                .unwrap()
                .run_round(inputs, &FaultPlan::none())
                .unwrap();
            let insec = InsecSession::new(quick_cfg(*n, 4, *seed))
                .unwrap()
                .run_round(inputs, &FaultPlan::none())
                .unwrap();
            let mut bon_cfg = quick_cfg(*n, 4, *seed);
            bon_cfg.progress_timeout = Duration::from_millis(500);
            let bon = BonSession::new(bon_cfg)
                .unwrap()
                .run_round(inputs, &FaultPlan::none())
                .unwrap();
            let close = |v: &[f64], tol: f64| {
                v.iter().zip(&expect).all(|(a, e)| (a - e).abs() < tol)
            };
            close(safe.average().unwrap(), 1e-6)
                && close(&insec.average, 1e-9)
                && close(&bon.average, 1e-5)
        },
    );
}

#[test]
fn prop_weighted_encode_decode_inverts() {
    testkit::check(
        "weighted-inverse",
        200,
        |rng| {
            let features = 1 + rng.next_below(20);
            let x: Vec<f64> =
                (0..features).map(|_| (rng.next_f64() - 0.5) * 100.0).collect();
            let w = 1.0 + rng.next_f64() * 10_000.0;
            (x, w)
        },
        |(x, w)| {
            let enc = weighted::encode(x, *w);
            let dec = weighted::decode(&enc).unwrap();
            dec.iter().zip(x).all(|(a, b)| (a - b).abs() < 1e-9 * (1.0 + b.abs()))
        },
    );
}

#[test]
fn prop_shamir_roundtrip() {
    use safe_agg::crypto::shamir;
    testkit::check(
        "shamir-roundtrip",
        100,
        |rng| {
            let secret = testkit::gen::bytes(rng, 64);
            let n = 3 + rng.next_below(8);
            let t = 2 + rng.next_below(n - 1);
            (secret, n as u64, t)
        },
        |(secret, n, t)| {
            let mut rng = DeterministicRng::seed(1);
            let xs: Vec<u64> = (1..=*n).collect();
            let shares = shamir::share_secret(secret, *t, &xs, &mut rng).unwrap();
            // Reconstruct from exactly t shares taken from the tail.
            let subset = &shares[shares.len() - *t..];
            shamir::reconstruct_secret(subset).unwrap() == *secret
        },
    );
}

#[test]
fn prop_envelope_roundtrip_all_modes() {
    use safe_agg::crypto::envelope::Envelope;
    use safe_agg::crypto::rsa::RsaKeyPair;
    use safe_agg::crypto::SymmetricKey;
    let mut keyrng = DeterministicRng::seed(99);
    let kp = RsaKeyPair::generate(512, &mut keyrng);
    let sym = SymmetricKey::generate(&mut keyrng);
    testkit::check(
        "envelope-roundtrip",
        60,
        |rng| {
            let v = testkit::gen::f64_vec(rng, 300);
            let mode = match rng.next_below(4) {
                0 => CipherMode::None,
                1 => CipherMode::RsaOnly,
                2 => CipherMode::Hybrid,
                _ => CipherMode::PreNegotiated,
            };
            let compress = rng.next_below(2) == 0;
            (v, mode, compress)
        },
        |(v, mode, compress)| {
            let mut rng = DeterministicRng::seed(7);
            let env = Envelope::seal(v, *mode, Some(&kp.public), Some(&sym), *compress, &mut rng)
                .unwrap();
            // Wire roundtrip too.
            let decoded = Envelope::decode(&env.encode()).unwrap();
            decoded.open(Some(&kp.private), Some(&sym)).unwrap() == *v
        },
    );
}

#[test]
fn prop_next_alive_routing() {
    use safe_agg::controller::state::GroupState;
    testkit::check(
        "next-alive",
        300,
        |rng| {
            let n = 3 + rng.next_below(30);
            let chain: Vec<u64> = (1..=n as u64).collect();
            let mut failed = std::collections::BTreeSet::new();
            for node in &chain {
                if rng.next_below(4) == 0 {
                    failed.insert(*node);
                }
            }
            let from = chain[rng.next_below(n)];
            (chain, failed, from)
        },
        |(chain, failed, from)| {
            let mut gs = GroupState::new(chain.clone());
            gs.failed = failed.clone();
            match gs.next_alive_after(*from) {
                Some(next) => {
                    // Must be in chain, not failed, not self (unless only
                    // survivor), and the *nearest* live successor.
                    if !chain.contains(&next) || failed.contains(&next) {
                        return false;
                    }
                    let pos = chain.iter().position(|n| n == from).unwrap();
                    for step in 1..chain.len() {
                        let cand = chain[(pos + step) % chain.len()];
                        if !failed.contains(&cand) {
                            return cand == next;
                        }
                    }
                    false
                }
                None => {
                    // Correct only when every other node failed.
                    chain.iter().all(|n| n == from || failed.contains(n))
                }
            }
        },
    );
}

#[test]
fn weighted_full_protocol_run() {
    // End-to-end §5.6: three learners with very different sample counts.
    let mut cfg = quick_cfg(3, 2, 5);
    cfg.weighted = true;
    let session = SafeSession::new(cfg).unwrap();
    let xs = [vec![2.0, -1.0], vec![5.0, 3.0], vec![8.0, 1.0]];
    let ws = [1000.0, 10000.0, 100.0];
    let inputs: Vec<Vec<f64>> =
        xs.iter().zip(&ws).map(|(x, &w)| weighted::encode(x, w)).collect();
    let result = session.run_round(&inputs, &FaultPlan::none()).unwrap();
    let avg = weighted::decode(result.average().unwrap()).unwrap();
    let total_w: f64 = ws.iter().sum();
    for f in 0..2 {
        let expect: f64 =
            xs.iter().zip(&ws).map(|(x, &w)| x[f] * w).sum::<f64>() / total_w;
        assert!((avg[f] - expect).abs() < 1e-6, "feature {f}: {} vs {}", avg[f], expect);
    }
}

#[test]
fn shuffled_chains_still_average_correctly() {
    // §8 discussion: chain order randomized between rounds; correctness
    // must be order-independent and the initiator must rotate.
    let mut cfg = quick_cfg(6, 3, 77);
    cfg.shuffle_chain_each_round = true;
    let session = SafeSession::new(cfg).unwrap();
    let inputs: Vec<Vec<f64>> = (1..=6).map(|i| vec![i as f64; 3]).collect();
    let expect = mean(&inputs);
    let mut initiators = std::collections::BTreeSet::new();
    for _ in 0..4 {
        let result = session.run_round(&inputs, &FaultPlan::none()).unwrap();
        for (a, e) in result.average().unwrap().iter().zip(&expect) {
            assert!((a - e).abs() < 1e-6);
        }
        initiators.insert(
            result.outcomes.iter().find(|o| o.was_initiator).unwrap().node,
        );
    }
    assert!(
        initiators.len() > 1,
        "shuffling should rotate the initiator across rounds: {initiators:?}"
    );
}

#[test]
fn staggered_polling_reduces_concurrent_polls() {
    // §5.9: staggering first polls lowers the controller's long-poll
    // connection pressure without breaking the protocol.
    let inputs: Vec<Vec<f64>> = (1..=8).map(|i| vec![i as f64]).collect();
    let expect = mean(&inputs);
    let run = |stagger: Duration| {
        let mut cfg = quick_cfg(8, 1, 3);
        // A small per-hop latency slows the chain enough that unstaggered
        // nodes reliably all park in get_aggregate before it reaches them.
        cfg.profile = DeviceProfile::edge();
        cfg.profile.network_hop = Duration::from_millis(4);
        cfg.stagger_step = stagger;
        let session = SafeSession::new(cfg).unwrap();
        session.controller.reset_poll_gauge();
        let result = session.run_round(&inputs, &FaultPlan::none()).unwrap();
        for (a, e) in result.average().unwrap().iter().zip(&expect) {
            assert!((a - e).abs() < 1e-6);
        }
        session.controller.peak_concurrent_polls()
    };
    let peak_unstaggered = run(Duration::ZERO);
    let peak_staggered = run(Duration::from_millis(60));
    assert!(
        peak_staggered < peak_unstaggered,
        "staggering should lower poll pressure: {peak_staggered} vs {peak_unstaggered}"
    );
}
