//! Thread-runtime vs event-runtime differential: the same seeded session
//! must produce *bit-identical* results under both executors.
//!
//! The event runtime (`runtime_exec`) re-expresses `run_learner` as a
//! state machine driven by a fixed worker pool; nothing about the
//! protocol is allowed to change. This test runs one churn scenario
//! twice — identical `SessionConfig` except `runtime`, identical seeded
//! Poisson schedule, identical inputs — and holds every per-round
//! observable equal: the average vector (exact float bits — chain order
//! is deterministic, so even FP rounding must agree), protocol message
//! counts, per-path message maps, rekey accounting, contributor counts,
//! and failover/merge/deadline counters.

use std::collections::BTreeMap;
use std::time::Duration;

use safe_agg::config::{DeviceProfile, RuntimeKind, SessionConfig};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::learner::faults::ChurnSchedule;
use safe_agg::protocols::SafeSession;

/// Everything a round reports that must not depend on the executor.
#[derive(Debug, Clone, PartialEq)]
struct RoundFingerprint {
    average: Vec<f64>,
    messages: u64,
    rekey_messages: u64,
    contributors: u64,
    progress_failovers: u64,
    initiator_failovers: u64,
    merged_groups: u64,
    reassigned_nodes: u64,
    deadline_exceeded: u64,
    net_retries: u64,
    net_drops: u64,
    dedup_posts: u64,
    per_path: BTreeMap<String, u64>,
    fanin_messages: u64,
    shard_messages: Vec<u64>,
}

fn cfg(n: usize, groups: usize, mode: CipherMode, runtime: RuntimeKind) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        groups,
        features: 3,
        mode,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        // Generous windows: no empty-poll retries, no spurious reposts or
        // elections under load — message counts stay schedule-determined.
        poll_time: Duration::from_secs(10),
        aggregation_timeout: Duration::from_secs(60),
        progress_timeout: Duration::from_secs(2),
        monitor_interval: Duration::from_millis(50),
        merge_floor: true,
        seed: Some(11),
        runtime,
        ..Default::default()
    }
}

fn inputs_for(n: usize, rounds: usize) -> Vec<Vec<Vec<f64>>> {
    (0..rounds)
        .map(|r| {
            (1..=n)
                .map(|i| {
                    (0..3)
                        .map(|f| (i * (r + 2)) as f64 + 0.125 * f as f64)
                        .collect()
                })
                .collect()
        })
        .collect()
}

fn run(cfg: SessionConfig, rounds: &[Vec<Vec<f64>>], churn: &ChurnSchedule) -> Vec<RoundFingerprint> {
    let session = SafeSession::new(cfg).unwrap();
    session
        .run_rounds(rounds, churn)
        .unwrap()
        .into_iter()
        .map(|r| RoundFingerprint {
            average: r.metrics.average.clone(),
            messages: r.metrics.messages,
            rekey_messages: r.metrics.rekey_messages,
            contributors: r.metrics.contributors,
            progress_failovers: r.metrics.progress_failovers,
            initiator_failovers: r.metrics.initiator_failovers,
            merged_groups: r.metrics.merged_groups,
            reassigned_nodes: r.metrics.reassigned_nodes,
            deadline_exceeded: r.metrics.deadline_exceeded,
            net_retries: r.metrics.net_retries,
            net_drops: r.metrics.net_drops,
            dedup_posts: r.metrics.dedup_posts,
            per_path: r.metrics.per_path.clone(),
            fanin_messages: r.metrics.fanin_messages,
            shard_messages: r.metrics.shard_messages.clone(),
        })
        .collect()
}

fn assert_identical(threads: &[RoundFingerprint], events: &[RoundFingerprint]) {
    assert_eq!(threads.len(), events.len(), "round counts differ");
    for (i, (t, e)) in threads.iter().zip(events).enumerate() {
        // Exact float bits, not approximate: both executors walk the same
        // deterministic chain order, so the FP sums must agree exactly.
        assert_eq!(
            t.average.iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
            e.average.iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
            "round {}: averages diverge\n threads={:?}\n events ={:?}",
            i + 1,
            t.average,
            e.average
        );
        assert_eq!(t, e, "round {}: fingerprints diverge", i + 1);
    }
}

/// The headline differential: n=24 in 6 subgroups, 3 rounds of seeded
/// Poisson churn with privacy-floor merge re-balancing on, full hybrid
/// encryption — threads vs events must match in every observable.
#[test]
fn threads_and_events_agree_under_poisson_churn() {
    let n = 24;
    let rounds = inputs_for(n, 3);
    let churn = ChurnSchedule::poisson(11, n, 3, 0.08, 0.5);
    assert!(!churn.is_empty(), "schedule must actually churn");

    let threads = run(cfg(n, 6, CipherMode::Hybrid, RuntimeKind::Threads), &rounds, &churn);
    let events = run(cfg(n, 6, CipherMode::Hybrid, RuntimeKind::Events), &rounds, &churn);
    assert_identical(&threads, &events);

    // Sanity: the scenario exercised something (a death shrank a round's
    // contributor set), so agreement is meaningful, not vacuous.
    assert!(
        threads.iter().any(|r| r.contributors < n as u64),
        "churn never removed a contributor: {threads:?}"
    );
}

/// Same differential through the SAF-mode (`CipherMode::None`) round-0
/// fast path — the shared-keypair setup and gated rekeys must behave
/// identically under both executors too.
#[test]
fn threads_and_events_agree_in_saf_mode() {
    let n = 12;
    let rounds = inputs_for(n, 2);
    let churn = ChurnSchedule::poisson(7, n, 2, 0.12, 0.6);

    let threads = run(cfg(n, 3, CipherMode::None, RuntimeKind::Threads), &rounds, &churn);
    let events = run(cfg(n, 3, CipherMode::None, RuntimeKind::Events), &rounds, &churn);
    assert_identical(&threads, &events);
}

/// The hostile-network differential: the same seeded lossy profile must
/// inject the *same* faults under both executors — the fault model keys
/// every draw on `(seed, node, path, attempt)`, never on threads or
/// wall-clock — so retry/drop/dedup counters, physical message counts,
/// and the averages all stay bit-identical. Loss is kept moderate so
/// retry budgets absorb every drop (no retry-exhaustion deaths): the
/// counts are then schedule-determined, not timing-determined.
#[test]
fn threads_and_events_agree_under_packet_loss() {
    let n = 12;
    let rounds = inputs_for(n, 2);
    let churn = ChurnSchedule::poisson(3, n, 2, 0.10, 0.5);
    let net = safe_agg::transport::NetProfile::parse(
        "lossy,lat-us=200,jitter-us=100,loss-req=0.08,loss-resp=0.05,seed=5",
    )
    .unwrap();
    let mk = |runtime| {
        let mut c = cfg(n, 3, CipherMode::Hybrid, runtime);
        c.net = net.clone();
        c
    };

    let threads = run(mk(RuntimeKind::Threads), &rounds, &churn);
    let events = run(mk(RuntimeKind::Events), &rounds, &churn);
    assert_identical(&threads, &events);

    // Sanity: the profile actually injected faults (≈100 faultable calls
    // at 8%/5% loss), so the agreement covered the retry/dedup machinery.
    let drops: u64 = threads.iter().map(|r| r.net_drops).sum();
    let retries: u64 = threads.iter().map(|r| r.net_retries).sum();
    assert!(drops > 0, "lossy differential injected no drops: {threads:?}");
    assert!(retries <= drops, "retries without a causing drop: {threads:?}");
}

/// The sharded-plane differential (K = 2): the same seeded session over
/// two shard controllers and a fan-in tier must be bit-identical between
/// executors, keep the chain traffic on the `4n + g` floor with the
/// fan-in surcharge counted separately (one partial post + one global
/// fetch per shard), and land every learner — across shard boundaries —
/// on the identical combined average.
#[test]
fn sharded_plane_threads_and_events_agree() {
    let n = 20;
    let g = 4u64;
    let rounds = inputs_for(n, 2);
    let churn = ChurnSchedule::none();
    let mk = |runtime| {
        let mut c = cfg(n, g as usize, CipherMode::Hybrid, runtime);
        c.shards = 2;
        c
    };

    let threads = run(mk(RuntimeKind::Threads), &rounds, &churn);
    let events = run(mk(RuntimeKind::Events), &rounds, &churn);
    assert_identical(&threads, &events);

    for fp in &threads {
        assert_eq!(
            fp.messages,
            4 * n as u64 + g,
            "sharding must not add chain traffic beyond 4n + g"
        );
        assert_eq!(fp.fanin_messages, 4, "2 shards × (partial post + global fetch)");
        assert_eq!(fp.contributors, n as u64);
        assert_eq!(fp.shard_messages.len(), 2, "one learner-path counter per shard");
        // Every chain message lands on exactly one shard counter; the
        // fan-in/monitor/key traffic stays on the session counter.
        assert_eq!(fp.shard_messages.iter().sum::<u64>(), fp.messages);
        assert!(fp.shard_messages.iter().all(|&m| m > 0), "both shards carried traffic");
    }
}

/// Sharded plane under churn: seeded Poisson deaths/rejoins with
/// privacy-floor merges (which may move nodes across shard boundaries)
/// must still be executor-invariant in every observable.
#[test]
fn sharded_plane_agrees_under_poisson_churn() {
    let n = 24;
    let rounds = inputs_for(n, 3);
    let churn = ChurnSchedule::poisson(11, n, 3, 0.08, 0.5);
    let mk = |runtime| {
        let mut c = cfg(n, 6, CipherMode::Hybrid, runtime);
        c.shards = 2;
        c
    };

    let threads = run(mk(RuntimeKind::Threads), &rounds, &churn);
    let events = run(mk(RuntimeKind::Events), &rounds, &churn);
    assert_identical(&threads, &events);
    assert!(
        threads.iter().any(|r| r.contributors < n as u64),
        "churn never removed a contributor: {threads:?}"
    );
}

/// Scraped-registry reconciliation: after a run, every mirrored
/// `safe_requests_total` / byte / fault counter series must equal the
/// `MessageStats` source it mirrors *bit-for-bit* — per path, per mirror
/// label — on both runtimes and on a K=2 sharded plane. The mirror is a
/// scrape-time collector, so this holds the observability plane to the
/// same accounting the formula tests pin, with no tolerance.
#[test]
fn registry_counters_reconcile_with_message_stats() {
    use safe_agg::metrics::{names, path_class};
    let n = 12;
    let rounds = inputs_for(n, 2);
    let churn = ChurnSchedule::poisson(7, n, 2, 0.12, 0.6);
    for (runtime, shards) in [
        (RuntimeKind::Threads, 1),
        (RuntimeKind::Events, 1),
        (RuntimeKind::Threads, 2),
        (RuntimeKind::Events, 2),
    ] {
        let mut c = cfg(n, 3, CipherMode::None, runtime);
        c.shards = shards;
        let session = SafeSession::new(c).unwrap();
        session.run_rounds(&rounds, &churn).unwrap();

        let registry = session.session_metrics().registry().clone();
        registry.collect();
        let sources = session.stats_by_mirror_label();
        assert_eq!(sources.len(), if shards > 1 { shards + 1 } else { 1 });
        for (label, stats) in &sources {
            let per_path = stats.per_path_stats();
            assert!(
                shards > 1 || !per_path.is_empty(),
                "{runtime:?} K={shards}: source {label} recorded nothing"
            );
            for (path, st) in &per_path {
                let labels =
                    [("path", path.as_str()), ("shard", label.as_str()), ("class", path_class(path))];
                assert_eq!(
                    registry.counter_value(names::REQUESTS_TOTAL, &labels),
                    Some(st.messages),
                    "{runtime:?} K={shards}: requests diverge for {path} on shard {label}"
                );
                assert_eq!(
                    registry.counter_value(names::REQUEST_BYTES_TOTAL, &labels),
                    Some(st.bytes_sent),
                    "{runtime:?} K={shards}: request bytes diverge for {path} on shard {label}"
                );
                assert_eq!(
                    registry.counter_value(names::RESPONSE_BYTES_TOTAL, &labels),
                    Some(st.bytes_received),
                    "{runtime:?} K={shards}: response bytes diverge for {path} on shard {label}"
                );
            }
            let fault_labels = [("shard", label.as_str())];
            assert_eq!(
                registry.counter_value(names::NET_RETRIES_TOTAL, &fault_labels),
                Some(stats.retries())
            );
            assert_eq!(
                registry.counter_value(names::NET_DROPS_TOTAL, &fault_labels),
                Some(stats.drops())
            );
            assert_eq!(
                registry.counter_value(names::DEDUP_POSTS_TOTAL, &fault_labels),
                Some(stats.dedup_posts())
            );
        }
        // No phantom series either: everything scraped traces back to a
        // (source, path) pair, so total scraped == total recorded.
        let scraped_total: u64 = registry
            .counter_series(names::REQUESTS_TOTAL)
            .into_iter()
            .map(|(_, v)| v)
            .sum();
        let recorded_total: u64 =
            sources.iter().map(|(_, s)| s.total()).sum();
        assert_eq!(
            scraped_total, recorded_total,
            "{runtime:?} K={shards}: scraped requests != recorded messages"
        );
    }
}

/// The deterministic (non-monitor) slice of the scraped registry is
/// itself executor-invariant: threads and events agree on every
/// per-path request count the schedule determines. Monitor-class series
/// are timing-dependent by design and excluded, mirroring the engine's
/// class-level filtering.
#[test]
fn registry_per_path_counters_agree_across_runtimes() {
    use safe_agg::metrics::{names, path_class};
    let n = 12;
    let rounds = inputs_for(n, 2);
    let churn = ChurnSchedule::poisson(7, n, 2, 0.12, 0.6);
    let totals = |runtime| {
        let session = SafeSession::new(cfg(n, 3, CipherMode::None, runtime)).unwrap();
        session.run_rounds(&rounds, &churn).unwrap();
        let registry = session.session_metrics().registry().clone();
        registry.collect();
        let mut by_path: BTreeMap<String, u64> = BTreeMap::new();
        for (labels, v) in registry.counter_series(names::REQUESTS_TOTAL) {
            let path = labels
                .iter()
                .find(|(k, _)| k == "path")
                .map(|(_, v)| v.clone())
                .expect("request series carries a path label");
            if path_class(&path) == "monitor" {
                continue;
            }
            *by_path.entry(path).or_insert(0) += v;
        }
        by_path
    };
    let threads = totals(RuntimeKind::Threads);
    let events = totals(RuntimeKind::Events);
    assert_eq!(threads, events, "non-monitor registry traffic diverges across runtimes");
    assert!(
        threads.keys().any(|p| path_class(p) == "chain"),
        "differential saw no chain traffic: {threads:?}"
    );
}

/// A failure-free single round under both runtimes lands exactly on the
/// paper's `4n (+ g)` floor — the differential holds at the formula
/// level, not just relative to each other.
#[test]
fn both_runtimes_hit_the_formula_floor() {
    let n = 10;
    let rounds = inputs_for(n, 1);
    let churn = ChurnSchedule::none();
    let g = 2u64;
    for runtime in [RuntimeKind::Threads, RuntimeKind::Events] {
        let fps = run(cfg(n, g as usize, CipherMode::Hybrid, runtime), &rounds, &churn);
        assert_eq!(fps.len(), 1);
        assert_eq!(
            fps[0].messages,
            4 * n as u64 + g,
            "{runtime:?}: failure-free round must cost 4n + g"
        );
        assert_eq!(fps[0].contributors, n as u64);
        assert_eq!(fps[0].progress_failovers, 0);
        assert_eq!(fps[0].deadline_exceeded, 0);
    }
}
