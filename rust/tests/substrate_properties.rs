//! Property tests on the substrates (bigint ring laws, JSON roundtrip,
//! HTTP long-polling) plus the paper's central *privacy* property: the
//! controller only ever holds ciphertext it cannot open.

use std::sync::Arc;
use std::time::Duration;

use safe_agg::config::{DeviceProfile, SessionConfig};
use safe_agg::crypto::bigint::BigUint;
use safe_agg::crypto::envelope::{CipherMode, Envelope};
use safe_agg::crypto::rng::{DeterministicRng, SecureRng};
use safe_agg::json::{self, Value};
use safe_agg::learner::faults::FaultPlan;
use safe_agg::protocols::SafeSession;
use safe_agg::testkit;

// ---- bigint ring laws ----

fn rand_big(rng: &mut DeterministicRng, max_bits: usize) -> BigUint {
    let bits = 1 + rng.next_below(max_bits);
    BigUint::random_bits(bits, rng)
}

#[test]
fn prop_bigint_distributive_law() {
    testkit::check(
        "bigint-distributive",
        100,
        |rng| (rand_big(rng, 400), rand_big(rng, 400), rand_big(rng, 200)),
        |(a, b, c)| a.add(b).mul(c) == a.mul(c).add(&b.mul(c)),
    );
}

#[test]
fn prop_bigint_div_rem_invariant() {
    testkit::check(
        "bigint-divrem",
        100,
        |rng| (rand_big(rng, 512), rand_big(rng, 256).add_u64(1)),
        |(a, d)| {
            let (q, r) = a.div_rem(d);
            r.lt(d) && q.mul(d).add(&r) == *a
        },
    );
}

#[test]
fn prop_bigint_modpow_multiplicative() {
    // (a*b)^e ≡ a^e * b^e (mod m) for odd m — exercises the Montgomery
    // path against itself via ring structure.
    testkit::check(
        "bigint-modpow-mult",
        25,
        |rng| {
            let mut m = rand_big(rng, 256).add_u64(3);
            if m.is_even() {
                m = m.add_u64(1);
            }
            let a = BigUint::random_below(&m, rng);
            let b = BigUint::random_below(&m, rng);
            let e = rand_big(rng, 32);
            (a, b, e, m)
        },
        |(a, b, e, m)| {
            let lhs = a.mulmod(b, m).modpow(e, m);
            let rhs = a.modpow(e, m).mulmod(&b.modpow(e, m), m);
            lhs == rhs
        },
    );
}

// ---- JSON roundtrip over random value trees ----

fn rand_value(rng: &mut DeterministicRng, depth: usize) -> Value {
    match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.next_below(2) == 0),
        2 => Value::Num((rng.next_f64() - 0.5) * 1e9),
        3 => Value::Str(testkit::gen::ascii_string(rng, 24)),
        4 => Value::Arr((0..rng.next_below(5)).map(|_| rand_value(rng, depth - 1)).collect()),
        _ => {
            let mut obj = Value::obj();
            for _ in 0..rng.next_below(5) {
                let key = testkit::gen::ascii_string(rng, 10);
                obj.set(&key, rand_value(rng, depth - 1));
            }
            obj
        }
    }
}

#[test]
fn prop_json_roundtrip_random_trees() {
    testkit::check(
        "json-roundtrip",
        300,
        |rng| rand_value(rng, 3),
        |v| match json::parse(&v.to_string()) {
            Ok(back) => back == *v,
            Err(_) => false,
        },
    );
}

#[test]
fn prop_json_string_escaping_exhaustive_bytes() {
    // Every ASCII byte + multibyte chars survive the escape/parse cycle.
    testkit::check(
        "json-string-bytes",
        100,
        |rng| {
            let len = rng.next_below(40);
            (0..len)
                .map(|_| match rng.next_below(10) {
                    0 => '"',
                    1 => '\\',
                    2 => '\n',
                    3 => '\u{1}',
                    4 => 'é',
                    5 => '😀',
                    _ => (32 + rng.next_below(95) as u8) as char,
                })
                .collect::<String>()
        },
        |s| {
            let v = Value::Str(s.clone());
            json::parse(&v.to_string()).map(|b| b.as_str() == Some(s.as_str())).unwrap_or(false)
        },
    );
}

// ---- the privacy property (paper §1/§5: broker sees only ciphertext) ----

#[test]
fn controller_never_sees_plaintext_aggregates() {
    // Run a real SAFE round with distinctive input values, intercepting
    // every message body at the transport layer; no chain message may
    // reveal an input value, and envelopes must not open without keys.
    use safe_agg::transport::{ClientTransport, Handler};

    struct Spy {
        inner: Arc<dyn Handler>,
        seen: std::sync::Mutex<Vec<safe_agg::blob::Blob>>,
    }
    impl Handler for Spy {
        fn handle(&self, path: &str, body: &Value) -> Value {
            if path == "/post_aggregate" {
                if let Some(agg) = body.blob_of("aggregate") {
                    self.seen.lock().unwrap().push(agg);
                }
            }
            self.inner.handle(path, body)
        }
    }

    let cfg = SessionConfig {
        n_nodes: 4,
        features: 2,
        mode: CipherMode::Hybrid,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        poll_time: Duration::from_millis(150),
        aggregation_timeout: Duration::from_secs(10),
        progress_timeout: Duration::from_secs(5),
        ..Default::default()
    };
    let session = SafeSession::new(cfg).unwrap();
    let spy = Arc::new(Spy {
        inner: session.controller.clone(),
        seen: std::sync::Mutex::new(Vec::new()),
    });
    // Drive the round through a spying transport on a *separate* client:
    // the learners run on their own transports, so instead intercept at
    // the controller mailbox — inspect what the broker stored.
    let secret_inputs: Vec<Vec<f64>> = vec![
        vec![1234.5678, -99.25],
        vec![42.42, 7.77],
        vec![3.14159, 2.71828],
        vec![888.888, -555.55],
    ];
    let result = session.run_round(&secret_inputs, &FaultPlan::none()).unwrap();
    let _ = spy; // spy transport validated structurally below instead

    // Recorded wire bytes: decode every aggregate envelope posted this
    // round from bytes_sent perspective — reconstruct via a fresh round
    // with an actual spy in the path.
    use safe_agg::controller::{Controller, ControllerConfig};
    let ctrl = Arc::new(Controller::new(ControllerConfig {
        poll_time: Duration::from_millis(100),
        ..Default::default()
    }));
    let spy2 = Arc::new(Spy { inner: ctrl.clone(), seen: std::sync::Mutex::new(Vec::new()) });
    // A minimal manual chain through the spy: seal → post → retrieve.
    let mut rng = DeterministicRng::seed(9);
    let kp = safe_agg::crypto::rsa::RsaKeyPair::generate(512, &mut rng);
    // The initiator masks before sealing (§5.1.1).
    let mask: Vec<f64> =
        (0..2).map(|_| safe_agg::learner::mask_value(rng.next_u64())).collect();
    let masked_input: Vec<f64> =
        secret_inputs[0].iter().zip(&mask).map(|(x, m)| x + m).collect();
    let env = Envelope::seal(
        &masked_input,
        CipherMode::Hybrid,
        Some(&kp.public),
        None,
        true,
        &mut rng,
    )
    .unwrap();
    let transport = safe_agg::transport::InProcTransport::new(spy2.clone());
    ctrl.handle(
        safe_agg::proto::CONFIGURE,
        &Value::object(vec![(
            "groups",
            Value::object(vec![("1", Value::Arr(vec![1u64.into(), 2u64.into(), 3u64.into()]))]),
        )]),
    );
    transport
        .call(
            safe_agg::proto::POST_AGGREGATE,
            &safe_agg::proto::post_aggregate(1, 2, env.to_blob().as_bytes(), 1),
        )
        .unwrap();
    let seen = spy2.seen.lock().unwrap().clone();
    assert_eq!(seen.len(), 1);
    for agg in &seen {
        // 1. No plaintext float leaks into the broker-visible bytes (check
        //    both the raw bytes and their text rendering).
        let agg_text = String::from_utf8_lossy(agg.as_bytes()).into_owned();
        for needle in ["1234.5678", "-99.25"] {
            assert!(!agg_text.contains(needle), "plaintext value leaked to controller");
            assert!(
                !agg
                    .as_bytes()
                    .windows(needle.len())
                    .any(|w| w == needle.as_bytes()),
                "plaintext value leaked to controller"
            );
        }
        // 2. The envelope does not open without the recipient's key.
        let env = Envelope::from_blob(agg).unwrap();
        let other = safe_agg::crypto::rsa::RsaKeyPair::generate(512, &mut rng);
        assert!(env.open(Some(&other.private), None).is_err());
        // 3. It does open with the right key, to the masked (≠ input) vector.
        let masked = env.open(Some(&kp.private), None).unwrap();
        assert_ne!(masked, secret_inputs[0], "initiator must mask before sending");
    }
    // And the full-session average was still correct.
    let expect0 =
        secret_inputs.iter().map(|v| v[0]).sum::<f64>() / secret_inputs.len() as f64;
    assert!((result.average().unwrap()[0] - expect0).abs() < 1e-6);
}

// ---- HTTP long-poll behaviour ----

#[test]
fn http_long_poll_blocks_until_data() {
    use safe_agg::controller::{Controller, ControllerConfig};
    use safe_agg::proto;
    use safe_agg::transport::http::{HttpServer, HttpTransport};
    use safe_agg::transport::ClientTransport;

    let ctrl = Arc::new(Controller::new(ControllerConfig {
        poll_time: Duration::from_secs(2),
        ..Default::default()
    }));
    use safe_agg::transport::Handler;
    ctrl.handle(
        proto::CONFIGURE,
        &Value::object(vec![(
            "groups",
            Value::object(vec![("1", Value::Arr(vec![1u64.into(), 2u64.into(), 3u64.into()]))]),
        )]),
    );
    let server = HttpServer::start("127.0.0.1:0", ctrl.clone()).unwrap();
    let url = server.url();

    // Client A parks in a long poll over real HTTP.
    let waiter = std::thread::spawn(move || {
        let client = HttpTransport::connect(&url).unwrap();
        let start = std::time::Instant::now();
        let resp = client.call(proto::GET_AGGREGATE, &proto::node_op(2, 1)).unwrap();
        (resp, start.elapsed())
    });
    std::thread::sleep(Duration::from_millis(200));
    // Client B posts; A must wake with the data well before poll_time.
    let poster = HttpTransport::connect(&server.url()).unwrap();
    poster
        .call(proto::POST_AGGREGATE, &proto::post_aggregate(1, 2, b"wire-blob", 1))
        .unwrap();
    let (resp, waited) = waiter.join().unwrap();
    assert_eq!(resp.blob_of("aggregate").unwrap().as_bytes(), b"wire-blob");
    assert!(waited >= Duration::from_millis(180), "poll returned before data existed");
    assert!(waited < Duration::from_secs(1), "condvar wakeup too slow: {waited:?}");
}
